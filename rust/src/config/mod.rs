//! Configuration system: a TOML-subset parser (serde/toml are not in the
//! offline vendor set) + typed experiment and fleet configs with presets.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string
//! ("…"), integer, float, and boolean values, `#` comments. That covers
//! every config this repo ships (`configs/*.toml`).

pub mod toml;

use crate::coordinator::fleet::{DetectorKind, Scenario};
use crate::coordinator::serve::ServeConfig;
use crate::coordinator::supervise::SuperviseConfig;
use crate::coordinator::sweep::SweepSpec;
use crate::coordinator::{ChannelConfig, MetricsMode};
use crate::data::SynthConfig;
use crate::exp::protocol::{ProtocolConfig, PruningSpec, Variant};
use crate::odl::AlphaKind;
use crate::storage::StorageConfig;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;
use toml::{TomlDoc, Value as TomlValue};

/// Typed experiment configuration (drives `odl-har run`).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub protocol: ProtocolConfig,
}

impl ExperimentConfig {
    pub fn from_file(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_str(&text)
    }

    pub fn from_str(text: &str) -> Result<ExperimentConfig> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow::anyhow!("config parse: {e}"))?;

        let variant_name = doc.get_str("model", "variant").unwrap_or("odlhash");
        let n_hidden = doc.get_int("model", "n_hidden").unwrap_or(128) as usize;
        let variant = match variant_name.to_ascii_lowercase().as_str() {
            "odlhash" => Variant::Odl(AlphaKind::Hash),
            "odlbase" => Variant::Odl(AlphaKind::Stored),
            "noodl" => Variant::NoOdl(AlphaKind::Hash),
            "dnn" => Variant::Dnn(vec![561, 512, 256, 6]),
            other => bail!("unknown model.variant '{other}'"),
        };

        let mut cfg = ProtocolConfig::new(variant, n_hidden);
        if let Some(t) = doc.get_int("experiment", "trials") {
            cfg.trials = t as usize;
        }
        if let Some(s) = doc.get_int("experiment", "seed") {
            cfg.master_seed = s as u64;
        }
        if let Some(f) = doc.get_float("experiment", "train_frac") {
            cfg.train_frac = f;
        }
        if let Some(e) = doc.get_float("teacher", "error_rate") {
            cfg.teacher_error = e;
        }
        cfg.pruning = match doc.get_str("pruning", "mode").unwrap_or("off") {
            "off" => PruningSpec::Off,
            "fixed" => {
                let theta = doc
                    .get_float("pruning", "theta")
                    .context("pruning.mode=fixed requires pruning.theta")?;
                PruningSpec::Fixed(theta as f32)
            }
            "auto" => PruningSpec::Auto {
                x: doc.get_int("pruning", "x").unwrap_or(10) as u32,
            },
            other => bail!("unknown pruning.mode '{other}'"),
        };
        if let Some(w) = doc.get_int("pruning", "warmup") {
            cfg.warmup = Some(w as usize);
        }
        apply_synth(&mut cfg.synth, &doc)?;
        Ok(ExperimentConfig { protocol: cfg })
    }
}

fn apply_synth(synth: &mut SynthConfig, doc: &TomlDoc) -> Result<()> {
    if let Some(v) = doc.get_int("data", "n_features") {
        synth.n_features = v as usize;
    }
    if let Some(v) = doc.get_int("data", "n_classes") {
        synth.n_classes = v as usize;
    }
    if let Some(v) = doc.get_int("data", "n_subjects") {
        synth.n_subjects = v as usize;
    }
    if let Some(v) = doc.get_int("data", "samples_per_cell") {
        synth.samples_per_cell = v as usize;
    }
    if let Some(v) = doc.get_float("data", "noise_sigma") {
        synth.noise_sigma = v;
    }
    if let Some(v) = doc.get_float("data", "drift_scale") {
        synth.drift_scale = v;
    }
    Ok(())
}

/// Fleet scenario config (drives `odl-har fleet`): `(scenario, seed,
/// workers)`. `workers = 0` in the TOML means "auto" — the caller resolves
/// it at startup via [`crate::util::auto_workers`]; the key defaults to 1
/// (the historical sequential run).
pub fn fleet_from_file(path: &Path) -> Result<(Scenario, u64, usize)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading config {}", path.display()))?;
    fleet_from_str(&text)
}

pub fn fleet_from_str(text: &str) -> Result<(Scenario, u64, usize)> {
    let doc = TomlDoc::parse(text).map_err(|e| anyhow::anyhow!("config parse: {e}"))?;
    scenario_from_doc(&doc)
}

/// Parse the `[fleet]` / `[pruning]` / `[teacher]` / `[channel]` /
/// `[data]` sections into a scenario (shared by the fleet and sweep
/// configs).
fn scenario_from_doc(doc: &TomlDoc) -> Result<(Scenario, u64, usize)> {
    let mut sc = Scenario::default();
    if let Some(v) = doc.get_int("fleet", "n_edges") {
        sc.n_edges = v as usize;
    }
    if let Some(v) = doc.get_int("fleet", "n_hidden") {
        sc.n_hidden = v as usize;
    }
    if let Some(v) = doc.get_float("fleet", "event_period_s") {
        sc.event_period_s = v;
    }
    if let Some(v) = doc.get_float("fleet", "horizon_s") {
        sc.horizon_s = v;
    }
    if let Some(v) = doc.get_float("fleet", "drift_at_s") {
        sc.drift_at_s = v;
    }
    if let Some(v) = doc.get_int("fleet", "train_target") {
        sc.train_target = v as usize;
    }
    if let Some(v) = doc.get_str("fleet", "detector") {
        sc.detector = DetectorKind::parse(v)
            .ok_or_else(|| anyhow::anyhow!("unknown fleet.detector '{v}'"))?;
    }
    if let Some(v) = doc.get_float("fleet", "eval_period_s") {
        sc.eval_period_s = v;
    }
    if let Some(v) = doc.get_int("fleet", "eval_samples") {
        sc.eval_samples = v as usize;
    }
    if let Some(v) = doc.get_bool("fleet", "eval_costs_power") {
        sc.eval_costs_power = v;
    }
    if let Some(v) = doc.get_int("fleet", "data_seed") {
        sc.data_seed = Some(v as u64);
    }
    // like the [sweep] keys, a present-but-malformed value is a rejected
    // typo, not a silently ignored one (get_str would drop `metrics = 1`)
    match doc.get("fleet", "metrics") {
        None => {}
        Some(TomlValue::Str(v)) => {
            sc.metrics =
                MetricsMode::parse(v).map_err(|e| anyhow::anyhow!("fleet.metrics: {e}"))?;
        }
        Some(other) => bail!(
            "fleet.metrics must be a string (\"full\" or \"aggregate\"), got {other:?}"
        ),
    }
    if let Some(v) = doc.get_float("pruning", "theta") {
        sc.fixed_theta = Some(v as f32);
    }
    if let Some(v) = doc.get_float("teacher", "error_rate") {
        sc.teacher_error = v;
    }
    let mut ch = ChannelConfig::default();
    if let Some(v) = doc.get_float("channel", "loss_prob") {
        ch.loss_prob = v;
    }
    if let Some(v) = doc.get_int("channel", "max_retries") {
        ch.max_retries = v as u32;
    }
    sc.channel = ch;
    apply_synth(&mut sc.synth, doc)?;
    let seed = doc.get_int("fleet", "seed").unwrap_or(1) as u64;
    // negatives clamp to 0 = auto rather than wrapping through `as usize`
    let workers = doc.get_int("fleet", "workers").unwrap_or(1).max(0) as usize;
    Ok((sc, seed, workers))
}

/// Scenario-sweep config (drives `odl-har sweep`): the `[sweep]` section
/// declares the grid axes over a `[fleet]`-section base scenario.
///
/// ```toml
/// [sweep]
/// seeds = [1, 2, 3]
/// thetas = ["auto", 0.1, 0.2]   # "auto" = the auto-θ ladder
/// edge_counts = [8, 64]
/// detectors = ["oracle", "centroid"]
/// n_hiddens = [64, 128, 256]    # hidden-layer widths
/// loss_probs = [0.0, 0.25]      # channel loss probabilities
/// teacher_errors = [0.0, 0.1]   # teacher label-error rates
/// workers = 0                   # cross-cell workers; 0 = auto
/// record_pca = false
/// memo_edge_state = true        # share provisioned edge cores across cells
/// ```
///
/// Omitted axes default to the base scenario's single value. Pin
/// `[fleet] data_seed` to share one provisioning-artifact build across
/// every simulation seed in the grid.
pub fn sweep_from_file(path: &Path) -> Result<SweepSpec> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading config {}", path.display()))?;
    sweep_from_str(&text)
}

/// A `[sweep]` axis key: absent is fine (the axis defaults), but a
/// present key MUST be an array — a scalar would otherwise be silently
/// ignored by `get_arr` and collapse the declared grid axis.
fn sweep_axis<'a>(doc: &'a TomlDoc, key: &str) -> Result<Option<&'a [TomlValue]>> {
    match doc.get("sweep", key) {
        None => Ok(None),
        Some(TomlValue::Arr(items)) => Ok(Some(items)),
        Some(other) => bail!("sweep.{key} must be an array (e.g. [1, 2]), got {other:?}"),
    }
}

/// The keys the `[sweep]` section understands — a present key outside
/// this list is a rejected typo, not a silently ignored one (a
/// misspelled axis would otherwise quietly collapse the declared grid).
const SWEEP_KEYS: &[&str] = &[
    "seeds",
    "thetas",
    "edge_counts",
    "detectors",
    "n_hiddens",
    "loss_probs",
    "teacher_errors",
    "workers",
    "record_pca",
    "memo_edge_state",
];

pub fn sweep_from_str(text: &str) -> Result<SweepSpec> {
    let doc = TomlDoc::parse(text).map_err(|e| anyhow::anyhow!("config parse: {e}"))?;
    for key in doc.section_keys("sweep") {
        ensure!(
            SWEEP_KEYS.contains(&key),
            "unknown [sweep] key '{key}' — valid keys: {}",
            SWEEP_KEYS.join(", ")
        );
    }
    let (base, seed, _fleet_workers) = scenario_from_doc(&doc)?;
    // present-but-wrong-typed scalars must error like a typo'd key would
    // — a silently dropped value makes the sweep lie about what it ran
    let sweep_bool = |key: &str, default: bool| -> Result<bool> {
        match doc.get("sweep", key) {
            None => Ok(default),
            Some(TomlValue::Bool(b)) => Ok(*b),
            Some(other) => bail!("sweep.{key} must be a boolean, got {other:?}"),
        }
    };
    let workers = match doc.get("sweep", "workers") {
        None => 0,
        Some(TomlValue::Int(i)) => (*i).max(0) as usize,
        Some(other) => bail!("sweep.workers must be an integer (0 = auto), got {other:?}"),
    };
    let mut spec = SweepSpec {
        seeds: vec![seed],
        thetas: vec![base.fixed_theta],
        edge_counts: vec![base.n_edges],
        detectors: vec![base.detector],
        n_hiddens: vec![base.n_hidden],
        loss_probs: vec![base.channel.loss_prob],
        teacher_errors: vec![base.teacher_error],
        workers,
        record_pca: sweep_bool("record_pca", false)?,
        memo_edge_state: sweep_bool("memo_edge_state", true)?,
        base,
    };
    if let Some(items) = sweep_axis(&doc, "seeds")? {
        spec.seeds = items
            .iter()
            .map(|v| match v {
                TomlValue::Int(i) => Ok(*i as u64),
                other => bail!("sweep.seeds entries must be integers, got {other:?}"),
            })
            .collect::<Result<_>>()?;
    }
    if let Some(items) = sweep_axis(&doc, "thetas")? {
        spec.thetas = items
            .iter()
            .map(|v| match v {
                TomlValue::Float(f) => Ok(Some(*f as f32)),
                TomlValue::Int(i) => Ok(Some(*i as f32)),
                TomlValue::Str(s) if s == "auto" => Ok(None),
                other => bail!(
                    "sweep.thetas entries must be numbers or \"auto\", got {other:?}"
                ),
            })
            .collect::<Result<_>>()?;
    }
    if let Some(items) = sweep_axis(&doc, "edge_counts")? {
        spec.edge_counts = items
            .iter()
            .map(|v| match v {
                TomlValue::Int(i) if *i > 0 => Ok(*i as usize),
                other => bail!(
                    "sweep.edge_counts entries must be positive integers, got {other:?}"
                ),
            })
            .collect::<Result<_>>()?;
    }
    if let Some(items) = sweep_axis(&doc, "detectors")? {
        spec.detectors = items
            .iter()
            .map(|v| match v {
                TomlValue::Str(s) => DetectorKind::parse(s).ok_or_else(|| {
                    anyhow::anyhow!("unknown sweep.detectors entry '{s}'")
                }),
                other => bail!("sweep.detectors entries must be strings, got {other:?}"),
            })
            .collect::<Result<_>>()?;
    }
    if let Some(items) = sweep_axis(&doc, "n_hiddens")? {
        spec.n_hiddens = items
            .iter()
            .map(|v| match v {
                TomlValue::Int(i) if *i > 0 => Ok(*i as usize),
                other => bail!(
                    "sweep.n_hiddens entries must be positive integers, got {other:?}"
                ),
            })
            .collect::<Result<_>>()?;
    }
    let prob_axis = |key: &str, out: &mut Vec<f64>| -> Result<()> {
        if let Some(items) = sweep_axis(&doc, key)? {
            *out = items
                .iter()
                .map(|v| {
                    let p = match v {
                        TomlValue::Float(f) => *f,
                        TomlValue::Int(i) => *i as f64,
                        other => bail!(
                            "sweep.{key} entries must be probabilities in [0, 1], got {other:?}"
                        ),
                    };
                    ensure!(
                        (0.0..=1.0).contains(&p),
                        "sweep.{key} entry {p} is outside [0, 1]"
                    );
                    Ok(p)
                })
                .collect::<Result<_>>()?;
        }
        Ok(())
    };
    prob_axis("loss_probs", &mut spec.loss_probs)?;
    prob_axis("teacher_errors", &mut spec.teacher_errors)?;
    ensure!(
        !spec.seeds.is_empty()
            && !spec.thetas.is_empty()
            && !spec.edge_counts.is_empty()
            && !spec.detectors.is_empty()
            && !spec.n_hiddens.is_empty()
            && !spec.loss_probs.is_empty()
            && !spec.teacher_errors.is_empty(),
        "sweep grid axes must be non-empty"
    );
    Ok(spec)
}

/// The keys the optional `[supervise]` section understands (knobs for
/// `odl-har sweep --shard auto`; see
/// `coordinator::supervise::SuperviseConfig`). Same contract as
/// [`SWEEP_KEYS`]: a present key outside this list is a rejected typo.
/// Per-run knobs (fault spec, workers) stay CLI-only, and CLI flags
/// override these values.
const SUPERVISE_KEYS: &[&str] = &[
    "shards",
    "retry_budget",
    "heartbeat_timeout_s",
    "grace_factor",
    "backoff_base_ms",
    "backoff_cap_ms",
    "poll_ms",
];

/// Parse the optional `[supervise]` section onto the default
/// [`SuperviseConfig`]:
///
/// ```toml
/// [supervise]
/// shards = 4                 # 0 = auto (one per core)
/// retry_budget = 2           # relaunches per shard before quarantine
/// heartbeat_timeout_s = 60.0 # kill a child whose file stops growing
/// grace_factor = 3.0         # pre-first-byte allowance, × timeout (≥ 1)
/// backoff_base_ms = 250      # first relaunch delay (doubles, capped)
/// backoff_cap_ms = 5000
/// poll_ms = 50
/// ```
pub fn supervise_from_str(text: &str) -> Result<SuperviseConfig> {
    let doc = TomlDoc::parse(text).map_err(|e| anyhow::anyhow!("config parse: {e}"))?;
    for key in doc.section_keys("supervise") {
        ensure!(
            SUPERVISE_KEYS.contains(&key),
            "unknown [supervise] key '{key}' — valid keys: {}",
            SUPERVISE_KEYS.join(", ")
        );
    }
    let mut cfg = SuperviseConfig::default();
    // present-but-wrong-typed values must error, not silently keep the
    // default — same rule as the [sweep] section
    let uint = |key: &str| -> Result<Option<u64>> {
        match doc.get("supervise", key) {
            None => Ok(None),
            Some(TomlValue::Int(i)) if *i >= 0 => Ok(Some(*i as u64)),
            Some(other) => {
                bail!("supervise.{key} must be a non-negative integer, got {other:?}")
            }
        }
    };
    if let Some(v) = uint("shards")? {
        cfg.shards = v as usize;
    }
    if let Some(v) = uint("retry_budget")? {
        cfg.retry_budget = v as usize;
    }
    if let Some(v) = uint("backoff_base_ms")? {
        cfg.backoff_base_ms = v;
    }
    if let Some(v) = uint("backoff_cap_ms")? {
        cfg.backoff_cap_ms = v;
    }
    if let Some(v) = uint("poll_ms")? {
        cfg.poll_ms = v;
    }
    match doc.get("supervise", "heartbeat_timeout_s") {
        None => {}
        Some(TomlValue::Float(f)) if *f > 0.0 => cfg.heartbeat_timeout_s = *f,
        Some(TomlValue::Int(i)) if *i > 0 => cfg.heartbeat_timeout_s = *i as f64,
        Some(other) => {
            bail!("supervise.heartbeat_timeout_s must be a positive number, got {other:?}")
        }
    }
    match doc.get("supervise", "grace_factor") {
        None => {}
        Some(TomlValue::Float(f)) if *f >= 1.0 => cfg.grace_factor = *f,
        Some(TomlValue::Int(i)) if *i >= 1 => cfg.grace_factor = *i as f64,
        Some(other) => {
            bail!("supervise.grace_factor must be a number ≥ 1, got {other:?}")
        }
    }
    Ok(cfg)
}

/// [`supervise_from_str`] over the sweep's config file (the section is
/// optional — a config without `[supervise]` yields the defaults).
pub fn supervise_from_file(path: &Path) -> Result<SuperviseConfig> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading config {}", path.display()))?;
    supervise_from_str(&text)
}

/// The keys the optional `[storage]` section understands (the result
/// storage backend for `sweep`/`merge`/`serve`; see
/// `storage::StorageConfig`). Same contract as [`SWEEP_KEYS`]: a present
/// key outside this list is a rejected typo. The `--storage` CLI flag
/// overrides `uri`.
const STORAGE_KEYS: &[&str] = &["uri", "retry_limit", "backoff_base_ms", "backoff_cap_ms"];

/// Parse the optional `[storage]` section onto the default
/// [`StorageConfig`] (no section, or no `uri`, means results stay on
/// plain local paths):
///
/// ```toml
/// [storage]
/// uri = "results/store"   # directory, or "remote://root" with the
///                         # `remote-storage` feature
/// retry_limit = 4         # total attempts per op on transient errors
/// backoff_base_ms = 25    # first retry delay (doubles, capped)
/// backoff_cap_ms = 1000
/// ```
pub fn storage_from_str(text: &str) -> Result<StorageConfig> {
    let doc = TomlDoc::parse(text).map_err(|e| anyhow::anyhow!("config parse: {e}"))?;
    storage_from_doc(&doc)
}

fn storage_from_doc(doc: &TomlDoc) -> Result<StorageConfig> {
    for key in doc.section_keys("storage") {
        ensure!(
            STORAGE_KEYS.contains(&key),
            "unknown [storage] key '{key}' — valid keys: {}",
            STORAGE_KEYS.join(", ")
        );
    }
    let mut cfg = StorageConfig::default();
    // present-but-wrong-typed values must error, not silently keep the
    // default — same rule as the [sweep]/[supervise] sections
    let uint = |key: &str| -> Result<Option<u64>> {
        match doc.get("storage", key) {
            None => Ok(None),
            Some(TomlValue::Int(i)) if *i >= 0 => Ok(Some(*i as u64)),
            Some(other) => {
                bail!("storage.{key} must be a non-negative integer, got {other:?}")
            }
        }
    };
    if let Some(v) = uint("retry_limit")? {
        ensure!(v >= 1, "storage.retry_limit must be ≥ 1 (total attempts)");
        cfg.retry_limit = v as usize;
    }
    if let Some(v) = uint("backoff_base_ms")? {
        cfg.backoff_base_ms = v;
    }
    if let Some(v) = uint("backoff_cap_ms")? {
        cfg.backoff_cap_ms = v;
    }
    match doc.get("storage", "uri") {
        None => {}
        Some(TomlValue::Str(s)) => cfg.uri = Some(s.clone()),
        Some(other) => bail!("storage.uri must be a string directory or URI, got {other:?}"),
    }
    Ok(cfg)
}

/// [`storage_from_str`] over a config file (the `[storage]` section is
/// optional — a config without it yields the defaults: no backend).
pub fn storage_from_file(path: &Path) -> Result<StorageConfig> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading config {}", path.display()))?;
    storage_from_str(&text)
}

/// The keys the optional `[serve]` section understands (knobs for
/// `odl-har serve`; see `coordinator::serve::ServeConfig`). Same contract
/// as [`SWEEP_KEYS`]: a present key outside this list is a rejected typo.
/// The scenario itself (model shape, teacher, pruning, data) comes from
/// the shared `[fleet]`/`[pruning]`/`[teacher]`/`[data]` sections.
const SERVE_KEYS: &[&str] = &[
    "bind",
    "max_clients",
    "queue_depth",
    "read_timeout_ms",
    "idle_timeout_ms",
    "retry_after_ms",
    "workers",
    "max_batch",
    "warmup",
    "snapshot",
];

/// Parse a serve config: the `[serve]` section onto defaults, plus the
/// scenario base shared with `fleet`/`sweep`:
///
/// ```toml
/// [serve]
/// bind = "127.0.0.1:4710"    # port 0 = ephemeral
/// max_clients = 8            # admission cap (busy beyond it)
/// queue_depth = 64           # per-connection input bound [KiB]
/// read_timeout_ms = 250      # socket deadline granularity
/// idle_timeout_ms = 30000    # disconnect stalled clients
/// retry_after_ms = 50        # back-off hint in busy/shed responses
/// workers = 0                # shard worker threads (0 = one per core)
/// max_batch = 16             # largest `events` frame accepted
/// warmup = 128               # pruning warmup (default: warmup_for(n_hidden))
/// snapshot = "serve.snap.json"
/// ```
pub fn serve_from_str(text: &str) -> Result<ServeConfig> {
    let doc = TomlDoc::parse(text).map_err(|e| anyhow::anyhow!("config parse: {e}"))?;
    for key in doc.section_keys("serve") {
        ensure!(
            SERVE_KEYS.contains(&key),
            "unknown [serve] key '{key}' — valid keys: {}",
            SERVE_KEYS.join(", ")
        );
    }
    let (sc, seed, _workers) = scenario_from_doc(&doc)?;
    let mut cfg = ServeConfig {
        seed,
        data_seed: sc.data_seed,
        teacher_error: sc.teacher_error,
        fixed_theta: sc.fixed_theta,
        n_hidden: sc.n_hidden,
        synth: sc.synth,
        ..ServeConfig::default()
    };
    // present-but-wrong-typed values must error, not silently keep the
    // default — same rule as the [sweep]/[supervise] sections
    let uint = |key: &str| -> Result<Option<u64>> {
        match doc.get("serve", key) {
            None => Ok(None),
            Some(TomlValue::Int(i)) if *i >= 0 => Ok(Some(*i as u64)),
            Some(other) => bail!("serve.{key} must be a non-negative integer, got {other:?}"),
        }
    };
    if let Some(v) = uint("max_clients")? {
        ensure!(v >= 1, "serve.max_clients must be ≥ 1");
        cfg.max_clients = v as usize;
    }
    if let Some(v) = uint("queue_depth")? {
        ensure!(v >= 1, "serve.queue_depth must be ≥ 1 (KiB)");
        cfg.queue_depth = v as usize;
    }
    if let Some(v) = uint("read_timeout_ms")? {
        ensure!(v >= 1, "serve.read_timeout_ms must be ≥ 1");
        cfg.read_timeout_ms = v;
    }
    if let Some(v) = uint("idle_timeout_ms")? {
        ensure!(v >= 1, "serve.idle_timeout_ms must be ≥ 1");
        cfg.idle_timeout_ms = v;
    }
    if let Some(v) = uint("retry_after_ms")? {
        cfg.retry_after_ms = v;
    }
    if let Some(v) = uint("workers")? {
        // 0 = one shard worker per available core
        cfg.workers = v as usize;
    }
    if let Some(v) = uint("max_batch")? {
        ensure!(v >= 1, "serve.max_batch must be ≥ 1");
        cfg.max_batch = v as usize;
    }
    if let Some(v) = uint("warmup")? {
        cfg.warmup = Some(v as usize);
    }
    match doc.get("serve", "bind") {
        None => {}
        Some(TomlValue::Str(s)) => cfg.bind = s.clone(),
        Some(other) => bail!("serve.bind must be a string address, got {other:?}"),
    }
    match doc.get("serve", "snapshot") {
        None => {}
        Some(TomlValue::Str(s)) => cfg.snapshot = Some(std::path::PathBuf::from(s)),
        Some(other) => bail!("serve.snapshot must be a string path, got {other:?}"),
    }
    // snapshots publish/restore through the shared [storage] section
    cfg.storage = storage_from_doc(&doc)?;
    Ok(cfg)
}

/// [`serve_from_str`] over a config file (the `[serve]` section is
/// optional — a scenario config without it yields the defaults).
pub fn serve_from_file(path: &Path) -> Result<ServeConfig> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading config {}", path.display()))?;
    serve_from_str(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
[model]
variant = "odlhash"
n_hidden = 256

[experiment]
trials = 5
seed = 99
train_frac = 0.8

[pruning]
mode = "auto"
x = 7

[teacher]
error_rate = 0.05
"#;

    #[test]
    fn experiment_config_parses() {
        let cfg = ExperimentConfig::from_str(SAMPLE).unwrap().protocol;
        assert_eq!(cfg.n_hidden, 256);
        assert_eq!(cfg.trials, 5);
        assert_eq!(cfg.master_seed, 99);
        assert!((cfg.train_frac - 0.8).abs() < 1e-12);
        assert!((cfg.teacher_error - 0.05).abs() < 1e-12);
        assert!(matches!(cfg.pruning, PruningSpec::Auto { x: 7 }));
        assert!(matches!(cfg.variant, Variant::Odl(AlphaKind::Hash)));
    }

    #[test]
    fn fixed_theta_requires_value() {
        let bad = "[pruning]\nmode = \"fixed\"\n";
        assert!(ExperimentConfig::from_str(bad).is_err());
        let good = "[pruning]\nmode = \"fixed\"\ntheta = 0.16\n";
        let cfg = ExperimentConfig::from_str(good).unwrap().protocol;
        assert!(matches!(cfg.pruning, PruningSpec::Fixed(t) if (t - 0.16).abs() < 1e-6));
    }

    #[test]
    fn unknown_variant_rejected() {
        assert!(ExperimentConfig::from_str("[model]\nvariant = \"transformer\"\n").is_err());
    }

    #[test]
    fn fleet_config_parses() {
        let text = r#"
[fleet]
n_edges = 8
horizon_s = 1200.0
detector = "centroid"
seed = 42
data_seed = 7
workers = 0

[channel]
loss_prob = 0.1
"#;
        let (sc, seed, workers) = fleet_from_str(text).unwrap();
        assert_eq!(sc.n_edges, 8);
        assert_eq!(sc.detector, DetectorKind::Centroid);
        assert!((sc.channel.loss_prob - 0.1).abs() < 1e-12);
        assert_eq!(sc.data_seed, Some(7));
        assert_eq!(seed, 42);
        assert_eq!(workers, 0, "0 stays 0 here; main resolves auto at startup");
    }

    #[test]
    fn fleet_workers_default_to_one_and_data_seed_to_derived() {
        let (sc, _, workers) = fleet_from_str("[fleet]\nn_edges = 2\n").unwrap();
        assert_eq!(workers, 1);
        assert_eq!(sc.data_seed, None);
        assert_eq!(sc.metrics, MetricsMode::Full, "full is the default");
    }

    #[test]
    fn fleet_metrics_mode_parses_and_rejects() {
        let (sc, _, _) = fleet_from_str("[fleet]\nmetrics = \"aggregate\"\n").unwrap();
        assert_eq!(sc.metrics, MetricsMode::Aggregate);
        let (sc, _, _) = fleet_from_str("[fleet]\nmetrics = \"full\"\n").unwrap();
        assert_eq!(sc.metrics, MetricsMode::Full);
        // unknown value: rejected, naming the offender
        let err = fleet_from_str("[fleet]\nmetrics = \"sketchy\"\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("sketchy"), "{err}");
        // present-but-wrong-typed: rejected, not silently ignored
        let err = fleet_from_str("[fleet]\nmetrics = 1\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("fleet.metrics"), "{err}");
        // the sweep parser shares the scenario base, so it rejects too
        assert!(sweep_from_str("[fleet]\nmetrics = \"sketchy\"\n").is_err());
        assert!(sweep_from_str("[fleet]\nmetrics = \"aggregate\"\n").is_ok());
    }

    #[test]
    fn sweep_config_parses_grid_axes() {
        let text = r#"
[fleet]
n_edges = 4
seed = 9
data_seed = 123

[sweep]
seeds = [1, 2]
thetas = ["auto", 0.2]
edge_counts = [4, 8]
detectors = ["oracle", "centroid"]
n_hiddens = [64, 128]
loss_probs = [0.0, 0.25]
teacher_errors = [0.0, 0.1]
workers = 3
record_pca = true
"#;
        let spec = sweep_from_str(text).unwrap();
        assert_eq!(spec.seeds, vec![1, 2]);
        assert_eq!(spec.thetas, vec![None, Some(0.2)]);
        assert_eq!(spec.edge_counts, vec![4, 8]);
        assert_eq!(
            spec.detectors,
            vec![DetectorKind::Oracle, DetectorKind::Centroid]
        );
        assert_eq!(spec.n_hiddens, vec![64, 128]);
        assert_eq!(spec.loss_probs, vec![0.0, 0.25]);
        assert_eq!(spec.teacher_errors, vec![0.0, 0.1]);
        assert_eq!(spec.workers, 3);
        assert!(spec.record_pca);
        assert!(spec.memo_edge_state, "edge-state memo defaults on");
        assert_eq!(spec.base.data_seed, Some(123));
        assert_eq!(spec.cells().len(), 128);
    }

    #[test]
    fn sweep_memo_edge_state_parses_and_validates() {
        let spec = sweep_from_str("[sweep]\nmemo_edge_state = false\n").unwrap();
        assert!(!spec.memo_edge_state);
        let err = sweep_from_str("[sweep]\nmemo_edge_state = 1\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("memo_edge_state"), "{err}");
    }

    #[test]
    fn sweep_scalar_keys_reject_wrong_types() {
        // the same strictness as memo_edge_state: a declared-but-mistyped
        // value must error, not silently fall back to the default
        let err = sweep_from_str("[sweep]\nrecord_pca = 1\n").unwrap_err().to_string();
        assert!(err.contains("record_pca"), "{err}");
        let err = sweep_from_str("[sweep]\nworkers = \"4\"\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("workers"), "{err}");
        // negative workers still clamp to auto rather than wrapping
        assert_eq!(sweep_from_str("[sweep]\nworkers = -2\n").unwrap().workers, 0);
    }

    #[test]
    fn sweep_rejects_unknown_axes() {
        // a typo'd axis must error with the valid keys listed, not
        // silently collapse the grid to the base scenario
        let err = sweep_from_str("[sweep]\nseedz = [1, 2]\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown [sweep] key 'seedz'"), "{err}");
        assert!(err.contains("edge_counts"), "{err}");
        let err = sweep_from_str("[sweep]\nn_hidden = [64]\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("'n_hidden'"), "{err}");
        // unknown keys outside [sweep] stay permitted (fleet/experiment
        // sections are shared with other subcommands)
        assert!(sweep_from_str("[fleet]\nn_edges = 2\ncomment_key = 1\n").is_ok());
    }

    #[test]
    fn sweep_rejects_out_of_range_probability_axes() {
        for bad in [
            "[sweep]\nloss_probs = [0.0, 1.01]\n",
            "[sweep]\nloss_probs = [-0.5]\n",
            "[sweep]\nteacher_errors = [7]\n",
            "[sweep]\nteacher_errors = [0.1, -1]\n",
        ] {
            let err = sweep_from_str(bad).unwrap_err().to_string();
            assert!(err.contains("[0, 1]") || err.contains("outside"), "{bad:?}: {err}");
        }
    }

    #[test]
    fn sweep_rejects_malformed_and_duplicate_toml() {
        // malformed array: parser error with the line number
        let err = sweep_from_str("[sweep]\nseeds = [1, 2\n").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        // nested arrays are not scalars: axis entry type error
        let err = sweep_from_str("[sweep]\nseeds = [[1]]\n").unwrap_err().to_string();
        assert!(err.contains("seeds"), "{err}");
        // duplicate keys are a parse error, not last-write-wins
        let err = sweep_from_str("[sweep]\nseeds = [1]\nseeds = [2]\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate key 'seeds'"), "{err}");
        let err = sweep_from_str("[fleet]\nn_edges = 2\nn_edges = 4\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate key 'n_edges'"), "{err}");
    }

    #[test]
    fn sweep_axes_default_to_base_scenario() {
        let spec = sweep_from_str("[fleet]\nn_edges = 6\nn_hidden = 48\nseed = 4\n").unwrap();
        assert_eq!(spec.seeds, vec![4]);
        assert_eq!(spec.thetas, vec![None]);
        assert_eq!(spec.edge_counts, vec![6]);
        assert_eq!(spec.detectors, vec![DetectorKind::Oracle]);
        assert_eq!(spec.n_hiddens, vec![48]);
        assert_eq!(spec.loss_probs, vec![0.0]);
        assert_eq!(spec.teacher_errors, vec![0.0]);
        assert_eq!(spec.workers, 0, "sweep default is auto");
        assert_eq!(spec.cells().len(), 1);
    }

    #[test]
    fn sweep_rejects_bad_axis_entries() {
        assert!(sweep_from_str("[sweep]\nthetas = [\"nope\"]\n").is_err());
        assert!(sweep_from_str("[sweep]\ndetectors = [\"kalman\"]\n").is_err());
        assert!(sweep_from_str("[sweep]\nedge_counts = [0]\n").is_err());
        assert!(sweep_from_str("[sweep]\nseeds = []\n").is_err());
        assert!(sweep_from_str("[sweep]\nn_hiddens = [0]\n").is_err());
        assert!(sweep_from_str("[sweep]\nn_hiddens = [\"wide\"]\n").is_err());
        assert!(sweep_from_str("[sweep]\nloss_probs = [1.5]\n").is_err());
        assert!(sweep_from_str("[sweep]\nloss_probs = [-0.1]\n").is_err());
        assert!(sweep_from_str("[sweep]\nteacher_errors = [2]\n").is_err());
        assert!(sweep_from_str("[sweep]\nteacher_errors = [\"oops\"]\n").is_err());
        // a present-but-scalar axis must error, not silently collapse the
        // grid to the base scenario's single value
        assert!(sweep_from_str("[sweep]\nseeds = 5\n").is_err());
        assert!(sweep_from_str("[sweep]\nedge_counts = 64\n").is_err());
        assert!(sweep_from_str("[sweep]\nloss_probs = 0.5\n").is_err());
    }

    #[test]
    fn sweep_prob_axes_accept_integer_endpoints() {
        let spec =
            sweep_from_str("[sweep]\nloss_probs = [0, 1]\nteacher_errors = [0]\n").unwrap();
        assert_eq!(spec.loss_probs, vec![0.0, 1.0]);
        assert_eq!(spec.teacher_errors, vec![0.0]);
    }

    #[test]
    fn defaults_when_sections_missing() {
        let cfg = ExperimentConfig::from_str("").unwrap().protocol;
        assert_eq!(cfg.n_hidden, 128);
        assert_eq!(cfg.trials, 20);
    }

    #[test]
    fn supervise_section_parses_onto_defaults() {
        // absent section = pure defaults
        let cfg = supervise_from_str("[fleet]\nn_edges = 2\n").unwrap();
        assert_eq!(cfg.shards, 0);
        assert_eq!(cfg.retry_budget, 2);
        assert!((cfg.heartbeat_timeout_s - 60.0).abs() < 1e-12);
        assert_eq!((cfg.backoff_base_ms, cfg.backoff_cap_ms), (250, 5000));
        assert_eq!(cfg.poll_ms, 50);

        let cfg = supervise_from_str(
            "[supervise]\nshards = 4\nretry_budget = 0\nheartbeat_timeout_s = 1.5\n\
             backoff_base_ms = 10\nbackoff_cap_ms = 40\npoll_ms = 5\n",
        )
        .unwrap();
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.retry_budget, 0);
        assert!((cfg.heartbeat_timeout_s - 1.5).abs() < 1e-12);
        assert_eq!((cfg.backoff_base_ms, cfg.backoff_cap_ms), (10, 40));
        assert_eq!(cfg.poll_ms, 5);
        // integer timeouts are accepted
        let cfg = supervise_from_str("[supervise]\nheartbeat_timeout_s = 2\n").unwrap();
        assert!((cfg.heartbeat_timeout_s - 2.0).abs() < 1e-12);
        // grace_factor: default 3, floats and integers ≥ 1 accepted
        assert!((cfg.grace_factor - 3.0).abs() < 1e-12);
        let cfg = supervise_from_str("[supervise]\ngrace_factor = 1.5\n").unwrap();
        assert!((cfg.grace_factor - 1.5).abs() < 1e-12);
        let cfg = supervise_from_str("[supervise]\ngrace_factor = 1\n").unwrap();
        assert!((cfg.grace_factor - 1.0).abs() < 1e-12);
    }

    #[test]
    fn serve_section_parses_onto_defaults_with_scenario_base() {
        // absent section = defaults + the shared scenario sections
        let cfg = serve_from_str(
            "[fleet]\nn_hidden = 48\nseed = 9\ndata_seed = 77\n\n\
             [pruning]\ntheta = 0.16\n\n[teacher]\nerror_rate = 0.1\n\n\
             [data]\nn_features = 24\nn_classes = 4\n",
        )
        .unwrap();
        assert_eq!(cfg.n_hidden, 48);
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.data_seed, Some(77));
        assert_eq!(cfg.data_seed(), 77);
        assert!((cfg.teacher_error - 0.1).abs() < 1e-12);
        assert_eq!(cfg.fixed_theta.map(f32::to_bits), Some(0.16f32.to_bits()));
        assert_eq!(cfg.synth.n_features, 24);
        assert_eq!(cfg.synth.n_classes, 4);
        assert_eq!(cfg.bind, "127.0.0.1:0");
        assert_eq!(cfg.max_clients, 8);
        assert_eq!(cfg.workers, 0, "default: one shard worker per core");
        assert_eq!(cfg.max_batch, 16);
        assert!(!cfg.thread_per_conn, "the legacy engine is bench-only, never config-on");
        assert!(cfg.warmup.is_none());
        assert!(cfg.snapshot.is_none());

        let cfg = serve_from_str(
            "[serve]\nbind = \"0.0.0.0:4710\"\nmax_clients = 3\nqueue_depth = 16\n\
             read_timeout_ms = 100\nidle_timeout_ms = 5000\nretry_after_ms = 25\n\
             workers = 2\nmax_batch = 8\n\
             warmup = 12\nsnapshot = \"out/serve.snap.json\"\n",
        )
        .unwrap();
        assert_eq!(cfg.bind, "0.0.0.0:4710");
        assert_eq!(cfg.max_clients, 3);
        assert_eq!(cfg.queue_depth, 16);
        assert_eq!(cfg.read_timeout_ms, 100);
        assert_eq!(cfg.idle_timeout_ms, 5000);
        assert_eq!(cfg.retry_after_ms, 25);
        assert_eq!(cfg.workers, 2);
        assert_eq!(cfg.max_batch, 8);
        assert_eq!(cfg.warmup, Some(12));
        assert_eq!(
            cfg.snapshot.as_deref(),
            Some(std::path::Path::new("out/serve.snap.json"))
        );
    }

    #[test]
    fn serve_rejects_unknown_keys_and_bad_types() {
        let err = serve_from_str("[serve]\nmax_client = 4\n").unwrap_err().to_string();
        assert!(err.contains("unknown [serve] key 'max_client'"), "{err}");
        assert!(err.contains("max_clients"), "{err}");
        // wrong types must error, not silently keep the default
        assert!(serve_from_str("[serve]\nmax_clients = \"many\"\n").is_err());
        assert!(serve_from_str("[serve]\nmax_clients = 0\n").is_err());
        assert!(serve_from_str("[serve]\nqueue_depth = 0\n").is_err());
        assert!(serve_from_str("[serve]\nread_timeout_ms = -5\n").is_err());
        assert!(serve_from_str("[serve]\nbind = 4710\n").is_err());
        assert!(serve_from_str("[serve]\nsnapshot = true\n").is_err());
        assert!(serve_from_str("[serve]\nwarmup = 1.5\n").is_err());
        assert!(serve_from_str("[serve]\nworkers = \"auto\"\n").is_err());
        assert!(serve_from_str("[serve]\nworkers = -1\n").is_err());
        assert!(serve_from_str("[serve]\nmax_batch = 0\n").is_err());
        assert!(serve_from_str("[serve]\nmax_batch = 1.5\n").is_err());
        // workers = 0 is valid (auto), unlike max_clients = 0
        assert_eq!(serve_from_str("[serve]\nworkers = 0\n").unwrap().workers, 0);
    }

    #[test]
    fn supervise_rejects_unknown_keys_and_bad_types() {
        let err = supervise_from_str("[supervise]\nretry_budgets = 3\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown [supervise] key 'retry_budgets'"), "{err}");
        // wrong types must error, not silently keep the default
        assert!(supervise_from_str("[supervise]\nshards = \"auto\"\n").is_err());
        assert!(supervise_from_str("[supervise]\nretry_budget = -1\n").is_err());
        assert!(supervise_from_str("[supervise]\nheartbeat_timeout_s = 0\n").is_err());
        assert!(supervise_from_str("[supervise]\nheartbeat_timeout_s = true\n").is_err());
        assert!(supervise_from_str("[supervise]\npoll_ms = 1.5\n").is_err());
        // grace_factor scales the timeout — values below 1 would *shrink*
        // the pre-first-byte allowance, which defeats its purpose
        assert!(supervise_from_str("[supervise]\ngrace_factor = 0.5\n").is_err());
        assert!(supervise_from_str("[supervise]\ngrace_factor = 0\n").is_err());
        assert!(supervise_from_str("[supervise]\ngrace_factor = \"big\"\n").is_err());
    }

    #[test]
    fn storage_section_parses_onto_defaults() {
        // absent section = defaults: no backend, results on plain paths
        let cfg = storage_from_str("[fleet]\nn_edges = 2\n").unwrap();
        assert_eq!(cfg, StorageConfig::default());
        assert!(cfg.uri.is_none());
        assert_eq!(cfg.retry_limit, 4);
        assert_eq!((cfg.backoff_base_ms, cfg.backoff_cap_ms), (25, 1000));

        let cfg = storage_from_str(
            "[storage]\nuri = \"results/store\"\nretry_limit = 2\n\
             backoff_base_ms = 5\nbackoff_cap_ms = 50\n",
        )
        .unwrap();
        assert_eq!(cfg.uri.as_deref(), Some("results/store"));
        assert_eq!(cfg.retry_limit, 2);
        assert_eq!((cfg.backoff_base_ms, cfg.backoff_cap_ms), (5, 50));
        // the serve config carries the same section
        let serve = serve_from_str("[storage]\nuri = \"snapdir\"\n").unwrap();
        assert_eq!(serve.storage.uri.as_deref(), Some("snapdir"));
    }

    #[test]
    fn storage_rejects_unknown_keys_and_bad_types() {
        let err = storage_from_str("[storage]\nretries = 3\n").unwrap_err().to_string();
        assert!(err.contains("unknown [storage] key 'retries'"), "{err}");
        assert!(err.contains("retry_limit"), "{err}");
        // wrong types must error, not silently keep the default
        assert!(storage_from_str("[storage]\nuri = 4\n").is_err());
        assert!(storage_from_str("[storage]\nretry_limit = 0\n").is_err());
        assert!(storage_from_str("[storage]\nretry_limit = \"lots\"\n").is_err());
        assert!(storage_from_str("[storage]\nbackoff_base_ms = -1\n").is_err());
        assert!(storage_from_str("[storage]\nbackoff_cap_ms = 1.5\n").is_err());
    }
}
