//! Minimal TOML-subset parser: `[section]`, `key = value` (string, int,
//! float, bool, single-line scalar arrays like `[1, 2, 3]` or
//! `["a", "b"]`), `#` comments. Enough for `configs/*.toml`; no nested
//! arrays, tables-in-arrays, multi-line strings/arrays, or commas inside
//! quoted array elements.

use std::collections::BTreeMap;

/// A parsed document: section → key → raw value.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    /// Single-line array of scalars (the sweep grid axes).
    Arr(Vec<Value>),
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let value = parse_value(value.trim())
                .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
            let key = key.trim().to_string();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            // TOML forbids redefining a key; silently keeping the last
            // write would make a typo'd config lie about what it ran
            let prev = doc
                .sections
                .entry(section.clone())
                .or_default()
                .insert(key.clone(), value);
            if prev.is_some() {
                return Err(format!(
                    "line {}: duplicate key '{}' in section '[{}]'",
                    lineno + 1,
                    key,
                    section
                ));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        match self.get(section, key)? {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key)? {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`horizon_s = 600`).
    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key)? {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key)? {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn get_arr(&self, section: &str, key: &str) -> Option<&[Value]> {
        match self.get(section, key)? {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Every key present in `section`, in sorted order (empty when the
    /// section is absent). Lets typed configs reject unknown keys instead
    /// of silently ignoring a typo'd axis.
    pub fn section_keys(&self, section: &str) -> Vec<&str> {
        self.sections
            .get(section)
            .map(|keys| keys.keys().map(String::as_str).collect())
            .unwrap_or_default()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array (arrays must be single-line)".to_string())?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_value_types() {
        let doc = TomlDoc::parse(
            "[a]\ns = \"hello\"\ni = 42\nf = 1.5\nneg = -3\nb = true\n",
        )
        .unwrap();
        assert_eq!(doc.get_str("a", "s"), Some("hello"));
        assert_eq!(doc.get_int("a", "i"), Some(42));
        assert_eq!(doc.get_float("a", "f"), Some(1.5));
        assert_eq!(doc.get_int("a", "neg"), Some(-3));
        assert_eq!(doc.get_bool("a", "b"), Some(true));
    }

    #[test]
    fn comments_and_blank_lines() {
        let doc = TomlDoc::parse("# header\n[s]\nk = 1 # trailing\n\nj = \"a#b\"\n").unwrap();
        assert_eq!(doc.get_int("s", "k"), Some(1));
        assert_eq!(doc.get_str("s", "j"), Some("a#b"));
    }

    #[test]
    fn parses_scalar_arrays() {
        let doc = TomlDoc::parse(
            "[s]\nseeds = [1, 2, 3]\nthetas = [0.1, \"auto\"]\nempty = []\n",
        )
        .unwrap();
        let seeds = doc.get_arr("s", "seeds").unwrap();
        assert_eq!(seeds, &[Value::Int(1), Value::Int(2), Value::Int(3)]);
        let thetas = doc.get_arr("s", "thetas").unwrap();
        assert_eq!(
            thetas,
            &[Value::Float(0.1), Value::Str("auto".to_string())]
        );
        assert_eq!(doc.get_arr("s", "empty").unwrap().len(), 0);
        // scalar accessors see arrays as a type mismatch
        assert!(doc.get_int("s", "seeds").is_none());
        // and non-arrays are not arrays
        let doc = TomlDoc::parse("[s]\nk = 1\n").unwrap();
        assert!(doc.get_arr("s", "k").is_none());
    }

    #[test]
    fn rejects_unterminated_array() {
        assert!(TomlDoc::parse("[s]\nk = [1, 2\n").is_err());
    }

    #[test]
    fn rejects_malformed_arrays_descriptively() {
        // every malformation names its line and never panics
        for (bad, needle) in [
            ("[s]\nk = [1, 2\n", "line 2"),
            ("[s]\nk = [1,, 2]\n", "line 2"),
            ("[s]\nk = [1 2]\n", "line 2"),
            ("[s]\nk = [\"open]\n", "line 2"),
            ("[s]\nk = [nope]\n", "line 2"),
        ] {
            let err = TomlDoc::parse(bad).unwrap_err();
            assert!(err.contains(needle), "{bad:?}: {err}");
        }
    }

    #[test]
    fn rejects_duplicate_keys() {
        let err = TomlDoc::parse("[s]\nk = 1\nk = 2\n").unwrap_err();
        assert!(err.contains("line 3"), "{err}");
        assert!(err.contains("duplicate key 'k'"), "{err}");
        assert!(err.contains("[s]"), "{err}");
        // a re-opened section is still the same namespace
        let err = TomlDoc::parse("[s]\nk = 1\n[t]\nj = 2\n[s]\nk = 3\n").unwrap_err();
        assert!(err.contains("line 6"), "{err}");
        assert!(err.contains("duplicate key 'k'"), "{err}");
        // same key in different sections is fine
        let doc = TomlDoc::parse("[s]\nk = 1\n[t]\nk = 2\n").unwrap();
        assert_eq!(doc.get_int("s", "k"), Some(1));
        assert_eq!(doc.get_int("t", "k"), Some(2));
    }

    #[test]
    fn section_keys_enumerate_only_that_section() {
        let doc = TomlDoc::parse("[a]\nx = 1\ny = 2\n[b]\nz = 3\n").unwrap();
        assert_eq!(doc.section_keys("a"), vec!["x", "y"]);
        assert_eq!(doc.section_keys("b"), vec!["z"]);
        assert!(doc.section_keys("missing").is_empty());
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = TomlDoc::parse("[s]\nk = 600\n").unwrap();
        assert_eq!(doc.get_float("s", "k"), Some(600.0));
    }

    #[test]
    fn errors_are_line_numbered() {
        let err = TomlDoc::parse("[s]\nbad line\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        let err = TomlDoc::parse("[unterminated\n").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let err = TomlDoc::parse("[s]\nk = \"open\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn missing_keys_are_none() {
        let doc = TomlDoc::parse("[s]\nk = 1\n").unwrap();
        assert!(doc.get("s", "missing").is_none());
        assert!(doc.get("missing", "k").is_none());
        assert!(doc.get_str("s", "k").is_none(), "type mismatch is None");
    }
}
