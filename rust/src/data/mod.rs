//! Dataset substrate.
//!
//! The paper evaluates on the UCI "Human Activity Recognition Using
//! Smartphones" dataset [1]: 561 engineered features, 6 activity classes,
//! 30 human subjects, 10 299 samples. That dataset is not redistributable
//! inside this offline environment, so the default data source is
//! [`synth`] — a generator calibrated to reproduce the three properties
//! the paper's evaluation depends on (see DESIGN.md §3):
//!
//! 1. per-subject clusters within each activity class (Figure 1),
//! 2. a distribution shift for held-out subjects that costs a NoODL model
//!    ≈10 accuracy points (Table 3),
//! 3. high sample redundancy, making >50 % of teacher queries prunable
//!    (Figure 3).
//!
//! [`uci`] loads the real dataset when `$HAR_DATASET_DIR` points at the
//! extracted UCI archive, so all experiments can also run on real data.

pub mod pca;
pub mod split;
pub mod synth;
pub mod uci;

pub use split::{DriftSplit, HELD_OUT_SUBJECTS};
pub use synth::{SynthConfig, SynthHar};

use crate::linalg::Mat;

/// A labelled dataset: features (rows × 561), class labels, subject ids.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub xs: Mat,
    pub labels: Vec<usize>,
    pub subjects: Vec<usize>,
    pub n_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.xs.rows
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn n_features(&self) -> usize {
        self.xs.cols
    }

    /// Select rows by predicate over (label, subject).
    pub fn filter<F: Fn(usize, usize) -> bool>(&self, pred: F) -> Dataset {
        let keep: Vec<usize> = (0..self.len())
            .filter(|&r| pred(self.labels[r], self.subjects[r]))
            .collect();
        self.take(&keep)
    }

    /// Materialize a row subset.
    pub fn take(&self, rows: &[usize]) -> Dataset {
        let cols = self.xs.cols;
        let mut data = Vec::with_capacity(rows.len() * cols);
        let mut labels = Vec::with_capacity(rows.len());
        let mut subjects = Vec::with_capacity(rows.len());
        for &r in rows {
            data.extend_from_slice(self.xs.row(r));
            labels.push(self.labels[r]);
            subjects.push(self.subjects[r]);
        }
        Dataset {
            xs: Mat::from_vec(rows.len(), cols, data),
            labels,
            subjects,
            n_classes: self.n_classes,
        }
    }

    /// Shuffle rows in place (used by the per-trial protocol).
    pub fn shuffle(&mut self, rng: &mut crate::util::rng::Rng64) {
        *self = self.shuffled(rng);
    }

    /// A shuffled copy — same draw sequence and row order as
    /// [`Self::shuffle`], without mutating `self`. This is what lets the
    /// fleet's shared provisioning artifacts keep one immutable
    /// standardized pool while each fleet derives its own seed-keyed
    /// ordering from it.
    pub fn shuffled(&self, rng: &mut crate::util::rng::Rng64) -> Dataset {
        let mut order: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut order);
        self.take(&order)
    }

    /// Split at `k` into (first k rows, rest).
    pub fn split_at(&self, k: usize) -> (Dataset, Dataset) {
        let k = k.min(self.len());
        let head: Vec<usize> = (0..k).collect();
        let tail: Vec<usize> = (k..self.len()).collect();
        (self.take(&head), self.take(&tail))
    }

    /// Per-class sample counts.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_classes];
        for &l in &self.labels {
            counts[l] += 1;
        }
        counts
    }
}

/// Feature standardization parameters (fit on train, applied everywhere —
/// the on-device core receives standardized features, as sensor front-ends
/// do fixed-scale normalization).
#[derive(Clone, Debug)]
pub struct Standardizer {
    pub mean: Vec<f32>,
    pub std: Vec<f32>,
}

impl Standardizer {
    pub fn fit(xs: &Mat) -> Standardizer {
        let n = xs.cols;
        let mut mean = vec![0.0f64; n];
        for r in 0..xs.rows {
            for (m, &v) in mean.iter_mut().zip(xs.row(r)) {
                *m += v as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= xs.rows.max(1) as f64;
        }
        let mut var = vec![0.0f64; n];
        for r in 0..xs.rows {
            for ((v, &x), m) in var.iter_mut().zip(xs.row(r)).zip(&mean) {
                let d = x as f64 - m;
                *v += d * d;
            }
        }
        let std: Vec<f32> = var
            .iter()
            .map(|&v| ((v / xs.rows.max(1) as f64).sqrt().max(1e-6)) as f32)
            .collect();
        Standardizer {
            mean: mean.iter().map(|&m| m as f32).collect(),
            std,
        }
    }

    pub fn apply(&self, xs: &mut Mat) {
        assert_eq!(xs.cols, self.mean.len());
        for r in 0..xs.rows {
            let cols = xs.cols;
            let row = &mut xs.data[r * cols..(r + 1) * cols];
            for ((x, &m), &s) in row.iter_mut().zip(&self.mean).zip(&self.std) {
                *x = (*x - m) / s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng64;

    fn tiny() -> Dataset {
        Dataset {
            xs: Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0], &[7.0, 8.0]]),
            labels: vec![0, 1, 0, 1],
            subjects: vec![1, 1, 2, 2],
            n_classes: 2,
        }
    }

    #[test]
    fn filter_by_subject() {
        let d = tiny().filter(|_, s| s == 2);
        assert_eq!(d.len(), 2);
        assert_eq!(d.subjects, vec![2, 2]);
        assert_eq!(d.xs.row(0), &[5.0, 6.0]);
    }

    #[test]
    fn split_at_partitions() {
        let (a, b) = tiny().split_at(3);
        assert_eq!(a.len(), 3);
        assert_eq!(b.len(), 1);
        assert_eq!(b.labels, vec![1]);
    }

    #[test]
    fn shuffle_preserves_pairing() {
        let mut d = tiny();
        let before: Vec<(f32, usize)> = (0..d.len()).map(|r| (d.xs.at(r, 0), d.labels[r])).collect();
        d.shuffle(&mut Rng64::new(3));
        for r in 0..d.len() {
            let x0 = d.xs.at(r, 0);
            let l = d.labels[r];
            assert!(before.contains(&(x0, l)), "pairing broken");
        }
    }

    #[test]
    fn standardizer_zero_mean_unit_var() {
        let mut rng = Rng64::new(7);
        let mut xs = Mat::zeros(500, 3);
        for r in 0..500 {
            *xs.at_mut(r, 0) = rng.normal_ms(5.0, 2.0) as f32;
            *xs.at_mut(r, 1) = rng.normal_ms(-3.0, 0.5) as f32;
            *xs.at_mut(r, 2) = rng.normal_ms(0.0, 1.0) as f32;
        }
        let st = Standardizer::fit(&xs);
        st.apply(&mut xs);
        let st2 = Standardizer::fit(&xs);
        for j in 0..3 {
            assert!(st2.mean[j].abs() < 1e-4, "mean {}", st2.mean[j]);
            assert!((st2.std[j] - 1.0).abs() < 1e-3, "std {}", st2.std[j]);
        }
    }

    #[test]
    fn class_counts() {
        assert_eq!(tiny().class_counts(), vec![2, 2]);
    }
}
