//! PCA by power iteration with deflation — enough to regenerate Figure 1's
//! 2-D per-class visualizations without an external eigensolver.
//!
//! The heavy steps all route through the kernel layer: the covariance is
//! a triangular-blocked [`kernels::gram`] (via [`Mat::gram`]), the power
//! iteration matvec is 8-lane chunked, deflation is the same symmetric
//! rank-1 kernel the OS-ELM P update uses, and projection is one
//! components-matrix matvec per row.

use crate::linalg::kernels;
use crate::linalg::Mat;
use crate::util::rng::Rng64;

/// Result of a k-component PCA.
#[derive(Clone, Debug)]
pub struct Pca {
    /// k × n principal directions (rows, unit norm).
    pub components: Mat,
    /// Explained variance per component.
    pub eigenvalues: Vec<f32>,
    /// Feature means removed before projection.
    pub mean: Vec<f32>,
}

impl Pca {
    /// Fit `k` components on the rows of `xs` via covariance-free power
    /// iteration (works on the n×n Gram of centered data; n ≤ 561 here).
    pub fn fit(xs: &Mat, k: usize, rng: &mut Rng64) -> Pca {
        let n = xs.cols;
        let rows = xs.rows.max(1);
        // center
        let mut mean = vec![0.0f32; n];
        for r in 0..xs.rows {
            for (m, &v) in mean.iter_mut().zip(xs.row(r)) {
                *m += v / rows as f32;
            }
        }
        let mut centered = xs.clone();
        for r in 0..centered.rows {
            let cols = centered.cols;
            let row = &mut centered.data[r * cols..(r + 1) * cols];
            for (x, &m) in row.iter_mut().zip(&mean) {
                *x -= m;
            }
        }
        // covariance (n×n)
        let mut cov = centered.gram();
        for v in cov.data.iter_mut() {
            *v /= rows as f32;
        }

        let mut components = Mat::zeros(k, n);
        let mut eigenvalues = Vec::with_capacity(k);
        for comp in 0..k {
            // power iteration
            let mut v: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            normalize(&mut v);
            let mut lambda = 0.0f32;
            for _ in 0..200 {
                let mut w = cov.matvec(&v);
                let nrm = norm(&w);
                if nrm < 1e-12 {
                    break;
                }
                for x in w.iter_mut() {
                    *x /= nrm;
                }
                let delta: f32 = v.iter().zip(&w).map(|(a, b)| (a - b).abs()).sum();
                v = w;
                lambda = nrm;
                if delta < 1e-7 {
                    break;
                }
            }
            // deflate: cov ← cov − λ v vᵀ (symmetric rank-1, upper
            // triangle + mirror — the same kernel as the OS-ELM P update)
            kernels::rank1_sym_update(&mut cov.data, n, &v, lambda);
            components.row_mut(comp).copy_from_slice(&v);
            eigenvalues.push(lambda);
        }
        Pca {
            components,
            eigenvalues,
            mean,
        }
    }

    /// Project rows of `xs` onto the components → (rows × k).
    pub fn transform(&self, xs: &Mat) -> Mat {
        let k = self.components.rows;
        let mut out = Mat::zeros(xs.rows, k);
        let mut centered = vec![0.0f32; xs.cols];
        for r in 0..xs.rows {
            for ((c, &x), &m) in centered.iter_mut().zip(xs.row(r)).zip(&self.mean) {
                *c = x - m;
            }
            // one k×n matvec per row (8-lane chunked per component)
            kernels::matvec(
                &self.components.data,
                k,
                xs.cols,
                &centered,
                out.row_mut(r),
            );
        }
        out
    }
}

fn norm(v: &[f32]) -> f32 {
    v.iter().map(|x| x * x).sum::<f32>().sqrt()
}

fn normalize(v: &mut [f32]) {
    let n = norm(v);
    if n > 1e-12 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Data with a known dominant direction.
    fn anisotropic(rng: &mut Rng64, rows: usize) -> Mat {
        let mut xs = Mat::zeros(rows, 4);
        for r in 0..rows {
            let t = rng.normal() as f32 * 5.0; // dominant axis = (1,1,0,0)/√2
            let s = rng.normal() as f32 * 0.5;
            *xs.at_mut(r, 0) = t + rng.normal() as f32 * 0.1;
            *xs.at_mut(r, 1) = t + rng.normal() as f32 * 0.1;
            *xs.at_mut(r, 2) = s;
            *xs.at_mut(r, 3) = rng.normal() as f32 * 0.1;
        }
        xs
    }

    #[test]
    fn finds_dominant_direction() {
        let mut rng = Rng64::new(5);
        let xs = anisotropic(&mut rng, 400);
        let pca = Pca::fit(&xs, 2, &mut rng);
        let c0 = pca.components.row(0);
        // dominant direction ≈ ±(1,1,0,0)/√2
        let expected = 1.0 / 2f32.sqrt();
        assert!(
            (c0[0].abs() - expected).abs() < 0.05 && (c0[1].abs() - expected).abs() < 0.05,
            "c0 = {:?}",
            c0
        );
        assert!(pca.eigenvalues[0] > pca.eigenvalues[1] * 5.0);
    }

    #[test]
    fn components_are_orthonormal() {
        let mut rng = Rng64::new(6);
        let xs = anisotropic(&mut rng, 300);
        let pca = Pca::fit(&xs, 3, &mut rng);
        for i in 0..3 {
            let ci = pca.components.row(i);
            assert!((norm(ci) - 1.0).abs() < 1e-3);
            for j in 0..i {
                let d = crate::linalg::mat::dot(ci, pca.components.row(j));
                assert!(d.abs() < 0.02, "components {i},{j} not orthogonal: {d}");
            }
        }
    }

    #[test]
    fn transform_centers_data() {
        let mut rng = Rng64::new(7);
        let xs = anisotropic(&mut rng, 200);
        let pca = Pca::fit(&xs, 2, &mut rng);
        let proj = pca.transform(&xs);
        for c in 0..2 {
            let mean: f32 = (0..proj.rows).map(|r| proj.at(r, c)).sum::<f32>() / proj.rows as f32;
            assert!(mean.abs() < 0.1, "projected mean {mean}");
        }
    }

    #[test]
    fn projected_variance_matches_eigenvalue() {
        let mut rng = Rng64::new(8);
        let xs = anisotropic(&mut rng, 500);
        let pca = Pca::fit(&xs, 1, &mut rng);
        let proj = pca.transform(&xs);
        let var: f32 =
            (0..proj.rows).map(|r| proj.at(r, 0).powi(2)).sum::<f32>() / proj.rows as f32;
        let rel = (var - pca.eigenvalues[0]).abs() / pca.eigenvalues[0];
        assert!(rel < 0.05, "variance {var} vs eigenvalue {}", pca.eigenvalues[0]);
    }
}
