//! Loader for the real UCI HAR dataset (optional).
//!
//! If `$HAR_DATASET_DIR` points at the extracted "UCI HAR Dataset"
//! directory (containing `train/X_train.txt`, `train/y_train.txt`,
//! `train/subject_train.txt` and the `test/` equivalents), every
//! experiment can run on the real data instead of the synthetic
//! substitute. Class labels are remapped 1..6 → 0..5.

use super::Dataset;
use crate::linalg::Mat;
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// Load train+test into a single pool (the paper re-splits by subject).
pub fn load_pool(dir: &Path) -> Result<Dataset> {
    let train = load_part(dir, "train")?;
    let test = load_part(dir, "test")?;
    Ok(concat(train, test))
}

/// Try the environment variable; Ok(None) if unset.
pub fn load_from_env() -> Result<Option<Dataset>> {
    match std::env::var("HAR_DATASET_DIR") {
        Ok(dir) if !dir.is_empty() => {
            let d = load_pool(Path::new(&dir))
                .with_context(|| format!("loading UCI HAR from {dir}"))?;
            Ok(Some(d))
        }
        _ => Ok(None),
    }
}

fn load_part(dir: &Path, part: &str) -> Result<Dataset> {
    let x_path = dir.join(part).join(format!("X_{part}.txt"));
    let y_path = dir.join(part).join(format!("y_{part}.txt"));
    let s_path = dir.join(part).join(format!("subject_{part}.txt"));

    let xs = parse_matrix(&std::fs::read_to_string(&x_path)
        .with_context(|| format!("reading {}", x_path.display()))?)?;
    let labels: Vec<usize> = parse_ints(&std::fs::read_to_string(&y_path)?)?
        .iter()
        .map(|&v| {
            ensure!((1..=6).contains(&v), "label {} out of 1..6", v);
            Ok(v as usize - 1)
        })
        .collect::<Result<_>>()?;
    let subjects: Vec<usize> = parse_ints(&std::fs::read_to_string(&s_path)?)?
        .iter()
        .map(|&v| v as usize)
        .collect();

    ensure!(
        xs.rows == labels.len() && xs.rows == subjects.len(),
        "row count mismatch: X {} / y {} / subject {}",
        xs.rows,
        labels.len(),
        subjects.len()
    );
    ensure!(xs.cols == 561, "expected 561 features, got {}", xs.cols);
    Ok(Dataset {
        xs,
        labels,
        subjects,
        n_classes: 6,
    })
}

fn parse_matrix(text: &str) -> Result<Mat> {
    let mut data = Vec::new();
    let mut rows = 0usize;
    let mut cols = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let vals: Vec<f32> = line
            .split_ascii_whitespace()
            .map(|t| t.parse::<f32>().with_context(|| format!("line {}", lineno + 1)))
            .collect::<Result<_>>()?;
        if rows == 0 {
            cols = vals.len();
        } else {
            ensure!(vals.len() == cols, "ragged row at line {}", lineno + 1);
        }
        data.extend_from_slice(&vals);
        rows += 1;
    }
    ensure!(rows > 0, "empty matrix file");
    Ok(Mat::from_vec(rows, cols, data))
}

fn parse_ints(text: &str) -> Result<Vec<i64>> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| l.trim().parse::<i64>().context("bad integer"))
        .collect()
}

fn concat(a: Dataset, b: Dataset) -> Dataset {
    assert_eq!(a.xs.cols, b.xs.cols);
    let mut data = a.xs.data;
    data.extend_from_slice(&b.xs.data);
    let mut labels = a.labels;
    labels.extend_from_slice(&b.labels);
    let mut subjects = a.subjects;
    subjects.extend_from_slice(&b.subjects);
    Dataset {
        xs: Mat::from_vec(a.xs.rows + b.xs.rows, b.xs.cols, data),
        labels,
        subjects,
        n_classes: a.n_classes.max(b.n_classes),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_matrix_basic() {
        let m = parse_matrix("1.0 2.0 3.0\n4.0 5.0 6.0\n").unwrap();
        assert_eq!((m.rows, m.cols), (2, 3));
        assert_eq!(m.at(1, 2), 6.0);
    }

    #[test]
    fn parse_matrix_rejects_ragged() {
        assert!(parse_matrix("1 2\n3\n").is_err());
        assert!(parse_matrix("").is_err());
    }

    #[test]
    fn parse_ints_basic() {
        assert_eq!(parse_ints("1\n2\n\n3\n").unwrap(), vec![1, 2, 3]);
        assert!(parse_ints("x\n").is_err());
    }

    #[test]
    fn load_from_env_none_when_unset() {
        // NB: test environment must not define HAR_DATASET_DIR
        if std::env::var("HAR_DATASET_DIR").is_err() {
            assert!(load_from_env().unwrap().is_none());
        }
    }

    #[test]
    fn load_part_roundtrip_from_tempdir() {
        let dir = std::env::temp_dir().join(format!("uci_test_{}", std::process::id()));
        let train = dir.join("train");
        std::fs::create_dir_all(&train).unwrap();
        // two samples, 561 features of zeros except first
        let mut xrow = vec!["0.0"; 561];
        xrow[0] = "1.5";
        let line = xrow.join(" ");
        std::fs::write(train.join("X_train.txt"), format!("{line}\n{line}\n")).unwrap();
        std::fs::write(train.join("y_train.txt"), "1\n6\n").unwrap();
        std::fs::write(train.join("subject_train.txt"), "9\n25\n").unwrap();
        let d = load_part(&dir, "train").unwrap();
        assert_eq!(d.len(), 2);
        assert_eq!(d.labels, vec![0, 5]);
        assert_eq!(d.subjects, vec![9, 25]);
        assert_eq!(d.xs.at(0, 0), 1.5);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
