//! Synthetic HAR generator — the statistically matched substitute for the
//! UCI HAR dataset (DESIGN.md §3 documents the substitution).
//!
//! Generative model, mirroring how the UCI features arise (per-window
//! statistics of body-worn IMU signals, strongly correlated within feature
//! groups, with subject-specific gait/posture offsets):
//!
//! ```text
//! x(class c, subject s) = proto[c] ⊙ (1 + gain[s]) + B·z + offset[s] + ε
//! ```
//!
//! * `proto[c]` — class prototype in R^n: piecewise-smooth pattern (the
//!   561 UCI features come in correlated bands; we build the prototype
//!   from a few random low-frequency components),
//! * `gain[s]`, `offset[s]` — per-subject multiplicative / additive
//!   idiosyncrasies. **Held-out subjects** (the paper's {9,14,16,19,25})
//!   draw these from a wider distribution (`drift_scale`×), producing the
//!   distribution shift of Figure 1 / Table 3,
//! * `B·z` — shared low-rank within-class variation (z ∈ R^r), giving the
//!   high sample redundancy that makes pruning effective (Figure 3),
//! * `ε` — small iid noise.

use super::{Dataset, HELD_OUT_SUBJECTS};
use crate::linalg::Mat;
use crate::util::rng::{Rng64, RngStream};

/// Generator parameters. Defaults are the calibrated values used by every
/// experiment harness (calibration tests live in this module; the
/// resulting Table-3-shaped numbers are recorded in EXPERIMENTS.md).
#[derive(Clone, Debug)]
pub struct SynthConfig {
    pub n_features: usize,
    pub n_classes: usize,
    pub n_subjects: usize,
    /// samples per (class, subject) pair in the train pool.
    pub samples_per_cell: usize,
    /// Low-rank within-class variation rank r.
    pub variation_rank: usize,
    /// Subject offset magnitude for in-distribution subjects.
    pub subject_sigma: f64,
    /// Multiplier on subject_sigma for held-out (drifted) subjects.
    pub drift_scale: f64,
    /// iid noise sigma.
    pub noise_sigma: f64,
    /// class prototype magnitude.
    pub proto_sigma: f64,
    /// low-rank variation magnitude.
    pub variation_sigma: f64,
    /// Fraction of samples blended toward a confusion-partner class
    /// (keeps the original label — models the inherently ambiguous
    /// sitting-vs-standing style samples that give UCI HAR its ≈95 %
    /// accuracy ceiling regardless of model capacity).
    pub confuse_frac: f64,
    /// Blend strength range [lo, hi] toward the partner prototype.
    pub confuse_blend: (f64, f64),
}

impl Default for SynthConfig {
    fn default() -> Self {
        Self {
            n_features: 561,
            n_classes: 6,
            n_subjects: 30,
            samples_per_cell: 57, // ≈ 10299 / (6·30)
            variation_rank: 8,
            subject_sigma: 0.55,
            drift_scale: 3.0,
            noise_sigma: 0.42,
            proto_sigma: 0.44,
            variation_sigma: 0.53,
            confuse_frac: 0.08,
            confuse_blend: (0.45, 0.6),
        }
    }
}

/// The generator: holds prototypes / subject parameters so that train and
/// test samples for the same subject share their idiosyncrasies.
/// Cheap to clone (a few subject/prototype matrices, no sample pool) —
/// each `Fleet` keeps its own copy so the provisioning pool can be
/// dropped as soon as construction finishes.
#[derive(Clone)]
pub struct SynthHar {
    pub cfg: SynthConfig,
    protos: Mat,           // n_classes × n
    variation: Mat,        // rank × n  (shared basis B)
    subject_offset: Mat,   // n_subjects × n
    subject_gain: Vec<f32>, // n_subjects
}

impl SynthHar {
    pub fn new(cfg: SynthConfig, rng: &mut Rng64) -> Self {
        let n = cfg.n_features;

        // Class prototypes: smooth random patterns (random walk low-pass) so
        // features are band-correlated like the UCI feature vector.
        let mut protos = Mat::zeros(cfg.n_classes, n);
        for c in 0..cfg.n_classes {
            let mut level = 0.0f64;
            for j in 0..n {
                // low-pass random walk, re-anchored per 40-feature band
                if j % 40 == 0 {
                    level = rng.normal_ms(0.0, cfg.proto_sigma);
                }
                level = 0.85 * level + 0.15 * rng.normal_ms(0.0, cfg.proto_sigma);
                *protos.at_mut(c, j) = level as f32;
            }
        }

        // Shared low-rank variation basis.
        let mut variation = Mat::zeros(cfg.variation_rank, n);
        for r in 0..cfg.variation_rank {
            let mut level = 0.0f64;
            for j in 0..n {
                level = 0.8 * level + 0.2 * rng.normal_ms(0.0, cfg.variation_sigma);
                *variation.at_mut(r, j) = level as f32;
            }
        }

        // Per-subject additive offsets (smooth) and multiplicative gains.
        // Held-out subjects draw from a `drift_scale`× wider distribution.
        let mut subject_offset = Mat::zeros(cfg.n_subjects, n);
        let mut subject_gain = Vec::with_capacity(cfg.n_subjects);
        for s in 0..cfg.n_subjects {
            let held_out = HELD_OUT_SUBJECTS.contains(&(s + 1)); // subjects are 1-based
            let sigma = cfg.subject_sigma * if held_out { cfg.drift_scale } else { 1.0 };
            let mut level = 0.0f64;
            for j in 0..n {
                level = 0.9 * level + 0.1 * rng.normal_ms(0.0, sigma);
                *subject_offset.at_mut(s, j) = level as f32;
            }
            let gain_sigma = 0.08 * if held_out { cfg.drift_scale } else { 1.0 };
            subject_gain.push(rng.normal_ms(0.0, gain_sigma) as f32);
        }

        Self {
            cfg,
            protos,
            variation,
            subject_offset,
            subject_gain,
        }
    }

    /// Draw one sample for (class, subject). `subject` is 1-based like the
    /// UCI ids. Generic over the RNG so the fleet's per-edge counter-based
    /// streams and the classic `Rng64` call sites share one body (the
    /// trait's samplers are formula-identical, so `Rng64` callers draw
    /// exactly what they always did).
    pub fn sample<R: RngStream>(&self, class: usize, subject: usize, rng: &mut R) -> Vec<f32> {
        assert!(class < self.cfg.n_classes);
        assert!((1..=self.cfg.n_subjects).contains(&subject));
        let s = subject - 1;
        let n = self.cfg.n_features;
        let gain = 1.0 + self.subject_gain[s];
        let z: Vec<f32> = (0..self.cfg.variation_rank)
            .map(|_| rng.normal() as f32)
            .collect();
        // Confusable sample: blend the prototype toward the "next" class
        // (fixed confusion partner, like sitting↔standing) while keeping
        // the label — an irreducible-error floor no capacity removes.
        let (partner, blend) = if rng.bernoulli(self.cfg.confuse_frac) {
            let partner = (class + 1) % self.cfg.n_classes;
            let (lo, hi) = self.cfg.confuse_blend;
            (partner, rng.uniform(lo, hi) as f32)
        } else {
            (class, 0.0)
        };
        let mut x = Vec::with_capacity(n);
        for j in 0..n {
            let proto =
                (1.0 - blend) * self.protos.at(class, j) + blend * self.protos.at(partner, j);
            let mut v = proto * gain + self.subject_offset.at(s, j);
            for (r, &zr) in z.iter().enumerate() {
                v += zr * self.variation.at(r, j);
            }
            v += rng.normal_ms(0.0, self.cfg.noise_sigma) as f32;
            x.push(v);
        }
        x
    }

    /// Generate the full pool: `samples_per_cell` per (class, subject).
    pub fn generate(&self, rng: &mut Rng64) -> Dataset {
        let cfg = &self.cfg;
        let rows = cfg.n_classes * cfg.n_subjects * cfg.samples_per_cell;
        let mut data = Vec::with_capacity(rows * cfg.n_features);
        let mut labels = Vec::with_capacity(rows);
        let mut subjects = Vec::with_capacity(rows);
        for subject in 1..=cfg.n_subjects {
            for class in 0..cfg.n_classes {
                for _ in 0..cfg.samples_per_cell {
                    data.extend_from_slice(&self.sample(class, subject, rng));
                    labels.push(class);
                    subjects.push(subject);
                }
            }
        }
        Dataset {
            xs: Mat::from_vec(rows, cfg.n_features, data),
            labels,
            subjects,
            n_classes: cfg.n_classes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> SynthConfig {
        SynthConfig {
            n_features: 60,
            n_classes: 4,
            n_subjects: 10,
            samples_per_cell: 12,
            ..Default::default()
        }
    }

    #[test]
    fn generate_shapes_and_coverage() {
        let mut rng = Rng64::new(1);
        let gen = SynthHar::new(small_cfg(), &mut rng);
        let d = gen.generate(&mut rng);
        assert_eq!(d.len(), 4 * 10 * 12);
        assert_eq!(d.n_features(), 60);
        let counts = d.class_counts();
        assert!(counts.iter().all(|&c| c == 10 * 12));
        for s in 1..=10 {
            assert!(d.subjects.contains(&s));
        }
    }

    #[test]
    fn classes_are_separated() {
        // Between-class distance must dominate within-class spread for
        // in-distribution subjects (so a model can learn at all).
        let mut rng = Rng64::new(2);
        let gen = SynthHar::new(small_cfg(), &mut rng);
        let a: Vec<Vec<f32>> = (0..20).map(|_| gen.sample(0, 1, &mut rng)).collect();
        let b: Vec<Vec<f32>> = (0..20).map(|_| gen.sample(1, 1, &mut rng)).collect();
        let centroid = |v: &[Vec<f32>]| -> Vec<f32> {
            let n = v[0].len();
            let mut c = vec![0.0f32; n];
            for x in v {
                for (ci, xi) in c.iter_mut().zip(x) {
                    *ci += xi / v.len() as f32;
                }
            }
            c
        };
        let ca = centroid(&a);
        let cb = centroid(&b);
        let between: f32 = ca.iter().zip(&cb).map(|(x, y)| (x - y).powi(2)).sum::<f32>().sqrt();
        let within: f32 = a
            .iter()
            .map(|x| {
                x.iter()
                    .zip(&ca)
                    .map(|(u, v)| (u - v).powi(2))
                    .sum::<f32>()
                    .sqrt()
            })
            .sum::<f32>()
            / a.len() as f32;
        assert!(
            between > within * 0.5,
            "between {between} vs within {within}"
        );
    }

    #[test]
    fn held_out_subjects_are_shifted() {
        // The offset of a held-out subject must be larger than that of an
        // in-distribution subject (this is the data drift).
        let mut rng = Rng64::new(3);
        let cfg = SynthConfig {
            n_subjects: 30,
            n_features: 60,
            ..small_cfg()
        };
        let gen = SynthHar::new(cfg, &mut rng);
        let norm = |s: usize| -> f32 {
            (0..60)
                .map(|j| gen.subject_offset.at(s - 1, j).powi(2))
                .sum::<f32>()
                .sqrt()
        };
        let held: f32 = HELD_OUT_SUBJECTS.iter().map(|&s| norm(s)).sum::<f32>() / 5.0;
        let in_dist: f32 = (1..=30)
            .filter(|s| !HELD_OUT_SUBJECTS.contains(s))
            .map(norm)
            .sum::<f32>()
            / 25.0;
        assert!(
            held > in_dist * 1.5,
            "held-out offset {held} vs in-dist {in_dist}"
        );
    }

    #[test]
    fn same_seed_same_data() {
        let mk = || {
            let mut rng = Rng64::new(9);
            let gen = SynthHar::new(small_cfg(), &mut rng);
            gen.generate(&mut rng)
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.xs.data, b.xs.data);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn sample_rejects_bad_args() {
        let mut rng = Rng64::new(1);
        let gen = SynthHar::new(small_cfg(), &mut rng);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            gen.sample(99, 1, &mut Rng64::new(0))
        }));
        assert!(r.is_err());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            gen.sample(0, 0, &mut Rng64::new(0))
        }));
        assert!(r.is_err());
    }
}
