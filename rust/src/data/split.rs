//! The paper's drift-split protocol (§3).
//!
//! From the full pool: samples of subjects {9, 14, 16, 19, 25} form the
//! **test1** (post-drift) set; everything else splits into **training**
//! and **test0** (pre-drift test). During the ODL phase, ≈60 % of test1 is
//! streamed for retraining; the remaining 40 % is the post-drift test set.

use super::Dataset;
use crate::util::rng::Rng64;

/// The human subjects removed from train/test0 and used as the drifted
/// distribution (paper §3, chosen there from the Figure-1 dimensionality
/// reduction).
pub const HELD_OUT_SUBJECTS: [usize; 5] = [9, 14, 16, 19, 25];

/// Fraction of test1 streamed for ODL retraining (paper: "approximately 60%").
pub const ODL_FRACTION: f64 = 0.6;

/// Materialized drift split.
#[derive(Clone, Debug)]
pub struct DriftSplit {
    /// Initial-training set (in-distribution subjects).
    pub train: Dataset,
    /// Pre-drift test set (in-distribution subjects, disjoint from train).
    pub test0: Dataset,
    /// ODL retraining stream (≈60 % of held-out-subject samples).
    pub odl_stream: Dataset,
    /// Post-drift test set (remaining held-out-subject samples).
    pub test1: Dataset,
}

impl DriftSplit {
    /// Build the paper's split from a pool. `train_frac` is the train share
    /// of the in-distribution data (UCI uses ≈70/30 train/test).
    pub fn build(pool: &Dataset, train_frac: f64, rng: &mut Rng64) -> DriftSplit {
        let in_dist = pool.filter(|_, s| !HELD_OUT_SUBJECTS.contains(&s));
        let held_out = pool.filter(|_, s| HELD_OUT_SUBJECTS.contains(&s));

        let mut in_dist = in_dist;
        in_dist.shuffle(rng);
        let k = (in_dist.len() as f64 * train_frac).round() as usize;
        let (train, test0) = in_dist.split_at(k);

        let mut held_out = held_out;
        held_out.shuffle(rng);
        let k1 = (held_out.len() as f64 * ODL_FRACTION).round() as usize;
        let (odl_stream, test1) = held_out.split_at(k1);

        DriftSplit {
            train,
            test0,
            odl_stream,
            test1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{SynthConfig, SynthHar};

    fn pool() -> Dataset {
        let mut rng = Rng64::new(4);
        let cfg = SynthConfig {
            n_features: 30,
            n_classes: 3,
            n_subjects: 30,
            samples_per_cell: 6,
            ..Default::default()
        };
        let gen = SynthHar::new(cfg, &mut rng);
        gen.generate(&mut rng)
    }

    #[test]
    fn split_is_a_partition() {
        let p = pool();
        let s = DriftSplit::build(&p, 0.7, &mut Rng64::new(1));
        let total = s.train.len() + s.test0.len() + s.odl_stream.len() + s.test1.len();
        assert_eq!(total, p.len());
    }

    #[test]
    fn held_out_subjects_only_in_post_drift_sets() {
        let p = pool();
        let s = DriftSplit::build(&p, 0.7, &mut Rng64::new(2));
        for subj in &s.train.subjects {
            assert!(!HELD_OUT_SUBJECTS.contains(subj));
        }
        for subj in &s.test0.subjects {
            assert!(!HELD_OUT_SUBJECTS.contains(subj));
        }
        for subj in &s.odl_stream.subjects {
            assert!(HELD_OUT_SUBJECTS.contains(subj));
        }
        for subj in &s.test1.subjects {
            assert!(HELD_OUT_SUBJECTS.contains(subj));
        }
    }

    #[test]
    fn odl_fraction_close_to_sixty_percent() {
        let p = pool();
        let s = DriftSplit::build(&p, 0.7, &mut Rng64::new(3));
        let held_total = (s.odl_stream.len() + s.test1.len()) as f64;
        let frac = s.odl_stream.len() as f64 / held_total;
        assert!((frac - ODL_FRACTION).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn different_seeds_shuffle_differently() {
        let p = pool();
        let a = DriftSplit::build(&p, 0.7, &mut Rng64::new(10));
        let b = DriftSplit::build(&p, 0.7, &mut Rng64::new(11));
        assert_ne!(a.train.labels, b.train.labels);
        // …but sizes are identical
        assert_eq!(a.train.len(), b.train.len());
    }
}
