//! `odl-har` — the leader CLI: regenerate every paper table/figure, run
//! custom experiments from TOML configs, and drive the fleet simulator.
//!
//! Subcommands (hand-rolled parser; clap is not in the offline vendor set):
//!
//! ```text
//! odl-har table1                      # SRAM size model (exact Table 1)
//! odl-har table2 [--trials N]        # params + accuracy vs SOTA
//! odl-har table3 [--trials N]        # accuracy before/after drift
//! odl-har table4 [--area] [--ablate-divider]
//! odl-har fig1   [--out DIR]         # per-class PCA CSVs
//! odl-har fig3   [--trials N] [--metric p1p2|el2n] [--out DIR]
//! odl-har fig4   [--trials N] [--out DIR]
//! odl-har run    --config FILE       # custom protocol experiment
//! odl-har fleet  [--config FILE] [--workers N] [--metrics full|aggregate] [--threaded]
//! odl-har sweep  --config FILE [--workers N] [--out FILE] [--resume] [--dry-run]
//!                [--shard I/N | --shard auto[:N]] [--retry-budget K]
//!                [--heartbeat-timeout SECS] [--inject-faults SPEC] [--fault-attempts K]
//! odl-har merge  --config FILE [--out FILE] SHARD_FILE...
//! odl-har serve  --config FILE [--bind ADDR] [--snapshot FILE] [--max-clients N]
//!                [--workers N] [--inject-faults SPEC]
//! odl-har loadgen --connect ADDR --config FILE [--client NAME] [--events N]
//!                [--batch K] [--retry-budget K] [--backoff-base-ms MS]
//!                [--backoff-cap-ms MS] [--reply-timeout-ms MS] [--shutdown]
//!                [--summary-out FILE] [--inject-faults SPEC]
//! odl-har artifacts-check            # verify PJRT artifacts load + run
//! ```
//!
//! Contract for misuse (pinned by `tests/cli_contract.rs`): an unknown
//! subcommand or a missing required argument prints the usage block to
//! **stderr** and exits non-zero; stdout stays clean so pipelines never
//! parse half a banner.
//!
//! Every `--workers` flag (and TOML `workers` key) treats `0` as "auto":
//! it resolves to `std::thread::available_parallelism()` once at startup.

use anyhow::{bail, Context, Result};
use odl_har::config;
use odl_har::exp::{fig1, fig3, fig4, protocol, table1, table2, table3, table4};
use odl_har::pruning::Metric;
use std::path::PathBuf;

/// Tiny argument scanner: flags (`--area`) and options (`--trials 5`).
struct Args {
    rest: Vec<String>,
}

impl Args {
    fn new(args: Vec<String>) -> Args {
        Args { rest: args }
    }

    fn flag(&mut self, name: &str) -> bool {
        if let Some(pos) = self.rest.iter().position(|a| a == name) {
            self.rest.remove(pos);
            true
        } else {
            false
        }
    }

    fn opt(&mut self, name: &str) -> Result<Option<String>> {
        if let Some(pos) = self.rest.iter().position(|a| a == name) {
            if pos + 1 >= self.rest.len() {
                bail!("{name} requires a value");
            }
            self.rest.remove(pos);
            Ok(Some(self.rest.remove(pos)))
        } else {
            Ok(None)
        }
    }

    fn opt_usize(&mut self, name: &str, default: usize) -> Result<usize> {
        Ok(match self.opt(name)? {
            Some(v) => v.parse().with_context(|| format!("bad {name} value"))?,
            None => default,
        })
    }

    /// Like [`Self::opt_usize`] but with no default — `None` when the
    /// flag is absent (used where a TOML value is the fallback).
    fn opt_usize_opt(&mut self, name: &str) -> Result<Option<usize>> {
        self.opt(name)?
            .map(|v| v.parse().with_context(|| format!("bad {name} value")))
            .transpose()
    }

    fn finish(self) -> Result<()> {
        if !self.rest.is_empty() {
            bail!("unrecognized arguments: {:?}", self.rest);
        }
        Ok(())
    }

    /// Like [`Self::opt`] but parsed as `u64` (the serve/loadgen
    /// millisecond knobs).
    fn opt_u64_opt(&mut self, name: &str) -> Result<Option<u64>> {
        self.opt(name)?
            .map(|v| v.parse().with_context(|| format!("bad {name} value")))
            .transpose()
    }

    /// Consume whatever remains after the flags/options as positional
    /// arguments (the `merge` subcommand's shard files).
    fn positional(self) -> Vec<String> {
        self.rest
    }
}

/// A required option was missing: usage to stderr (the CLI misuse
/// contract), then a non-zero exit via the error return.
fn require(opt: Option<String>, what: &str) -> Result<String> {
    match opt {
        Some(v) => Ok(v),
        None => {
            eprintln!("{USAGE}");
            bail!("{what}");
        }
    }
}

fn results_dir(args: &mut Args) -> Result<PathBuf> {
    let dir = args
        .opt("--out")?
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

fn main() -> Result<()> {
    odl_har::util::logging::init();
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_help();
        return Ok(());
    }
    let cmd = argv.remove(0);
    let mut args = Args::new(argv);

    match cmd.as_str() {
        "table1" => {
            args.finish()?;
            print!("{}", table1::run().render());
        }
        "table2" => {
            let trials = args.opt_usize("--trials", 20)?;
            args.finish()?;
            print!("{}", table2::run_table(trials)?.render());
        }
        "table3" => {
            let trials = args.opt_usize("--trials", 20)?;
            args.finish()?;
            let (t, _) = table3::run_table(trials)?;
            print!("{}", t.render());
        }
        "table4" => {
            let area = args.flag("--area");
            let ablate = args.flag("--ablate-divider");
            args.finish()?;
            print!("{}", table4::run(area).render());
            if ablate {
                print!("{}", table4::divider_ablation().render());
            }
        }
        "fig1" => {
            let out = results_dir(&mut args)?;
            args.finish()?;
            let mut data_rng = odl_har::util::rng::Rng64::new(0xDA7A_5EED);
            let pool = match odl_har::data::uci::load_from_env()? {
                Some(real) => real,
                None => odl_har::data::SynthHar::new(
                    odl_har::data::SynthConfig::default(),
                    &mut data_rng,
                )
                .generate(&mut data_rng),
            };
            print!("{}", fig1::run(&pool, &out, 7)?.render());
        }
        "fig3" => {
            let trials = args.opt_usize("--trials", 20)?;
            let metric = match args.opt("--metric")?.as_deref() {
                None | Some("p1p2") => Metric::P1P2,
                Some("el2n") => Metric::ErrorL2,
                Some(other) => bail!("unknown metric '{other}' (p1p2|el2n)"),
            };
            let out = results_dir(&mut args)?;
            args.finish()?;
            let points = fig3::sweep(trials, metric)?;
            let (t, csv) = fig3::render(&points, trials, metric)?;
            print!("{}", t.render());
            let path = out.join("fig3.csv");
            std::fs::write(&path, csv)?;
            println!("csv: {}", path.display());
            if let Some((red, drop)) = fig3::auto_headline(&points) {
                println!(
                    "Auto: comm reduction {red:.1} % (paper: 55.7 %), accuracy drop {drop:.1} pt (paper: 0.9 pt)"
                );
            }
        }
        "fig4" => {
            let trials = args.opt_usize("--trials", 20)?;
            let out = results_dir(&mut args)?;
            args.finish()?;
            let points = fig3::sweep(trials, Metric::P1P2)?;
            let (t, csv) = fig4::run_fig(&points)?;
            print!("{}", t.render());
            let path = out.join("fig4.csv");
            std::fs::write(&path, csv)?;
            println!("csv: {}", path.display());
            for (period, red) in fig4::auto_reductions(&points) {
                println!("Auto reduction @ 1/{period:.0}s events: {red:.1} %");
            }
        }
        "run" => {
            let cfg_path = require(args.opt("--config")?, "run requires --config FILE")?;
            args.finish()?;
            let cfg = config::ExperimentConfig::from_file(&PathBuf::from(cfg_path))?.protocol;
            let agg = protocol::run(&cfg)?;
            println!("{}", agg.label);
            println!(
                "before {:.1}±{:.1}  after {:.1}±{:.1}  comm {:.1} %  queries {:.0}",
                agg.before.mean(),
                agg.before.std(),
                agg.after.mean(),
                agg.after.std(),
                agg.comm.mean(),
                agg.queries.mean()
            );
        }
        "fleet" => {
            let threaded = args.flag("--threaded");
            let workers_cli = args.opt_usize_opt("--workers")?;
            let cfg_path = args.opt("--config")?;
            let metrics_cli = args.opt("--metrics")?;
            args.finish()?;
            let (mut scenario, seed, workers_toml) = match cfg_path {
                Some(p) => config::fleet_from_file(&PathBuf::from(p))?,
                None => (odl_har::coordinator::Scenario::default(), 1, 1),
            };
            // CLI beats TOML, same as --workers
            if let Some(m) = metrics_cli {
                scenario.metrics = odl_har::coordinator::MetricsMode::parse(&m)
                    .map_err(|e| anyhow::anyhow!("--metrics: {e}"))?;
            }
            // CLI beats TOML; 0 means auto (available_parallelism),
            // resolved once at startup
            let workers = odl_har::util::auto_workers(workers_cli.unwrap_or(workers_toml));
            if threaded {
                let counters =
                    odl_har::coordinator::Fleet::run_threaded(&scenario, seed, 600)?;
                for (id, (queries, trained)) in counters.iter().enumerate() {
                    println!("edge {id}: queries {queries}, trained {trained}");
                }
            } else {
                // both construction and the event loop ride the worker
                // budget; either path is bitwise identical to sequential
                // for any count, so --workers only changes wall time
                let fleet = odl_har::coordinator::Fleet::new_parallel(
                    odl_har::coordinator::fleet::FleetConfig { scenario, seed },
                    workers,
                )?;
                let report = fleet.run_parallel(workers);
                let n_edges = report
                    .aggregate
                    .as_ref()
                    .map(|a| a.n_edges as usize)
                    .unwrap_or(report.per_edge.len());
                println!(
                    "fleet: {} edges, horizon {:.0}s, {} worker(s), teacher queries {}, channel fail {}/{}",
                    n_edges,
                    report.horizon_s,
                    workers.max(1),
                    report.teacher_queries,
                    report.channel_failures,
                    report.channel_attempts
                );
                if let Some(agg) = &report.aggregate {
                    // aggregate mode: O(1) report — sketches instead of
                    // per-edge rows
                    println!(
                        "aggregate: events {} queries {} skips {} trained {} query failures {} mode switches {}",
                        agg.events,
                        agg.total_queries,
                        agg.skips,
                        agg.trained,
                        agg.query_failures,
                        agg.mode_switches,
                    );
                    println!(
                        "aggregate: energy {:.1} mJ, power mW p50 {:.3} p90 {:.3} p99 {:.3}, accuracy p50 {:.3} p90 {:.3}",
                        agg.total_energy_mj,
                        agg.power_mw.p50(),
                        agg.power_mw.p90(),
                        agg.power_mw.p99(),
                        agg.accuracy.p50(),
                        agg.accuracy.p90(),
                    );
                    println!(
                        "aggregate: distinct visited cells ~{:.0}, distinct edge states ~{:.0}",
                        agg.visited_cells.estimate(),
                        agg.edge_states.estimate(),
                    );
                } else {
                    for (id, m) in report.per_edge.iter().enumerate() {
                        println!(
                            "edge {id}: events {} queries {} skips {} trained {} comm {:.1}% power {:.2} mW (core {:.2} + radio {:.2})",
                            m.events,
                            m.queries,
                            m.skips,
                            m.trained,
                            m.comm_fraction() * 100.0,
                            m.mean_power_mw(report.horizon_s),
                            m.core_energy_mj / report.horizon_s,
                            m.radio_energy_mj / report.horizon_s,
                        );
                    }
                }
            }
        }
        "sweep" => {
            let cfg_path = require(args.opt("--config")?, "sweep requires --config FILE")?;
            let dry_run = args.flag("--dry-run");
            let resume = args.flag("--resume");
            let workers_cli = args.opt_usize_opt("--workers")?;
            let shard_raw = args.opt("--shard")?;
            let retry_budget = args.opt_usize_opt("--retry-budget")?;
            let heartbeat = args
                .opt("--heartbeat-timeout")?
                .map(|v| {
                    v.parse::<f64>()
                        .with_context(|| format!("bad --heartbeat-timeout value '{v}'"))
                })
                .transpose()?;
            let fault_spec = args.opt("--inject-faults")?;
            let fault_attempts = args.opt_usize_opt("--fault-attempts")?;
            let storage_uri = args.opt("--storage")?;
            // `--shard auto[:N]` switches to the self-healing supervisor
            // (coordinator::supervise): spawn one child per shard, watch,
            // relaunch onto --resume, quarantine, auto-merge
            let auto = match shard_raw.as_deref() {
                Some("auto") => Some(0usize), // 0 = one shard per worker
                Some(s) => match s.strip_prefix("auto:") {
                    Some(n) => Some(
                        n.parse::<usize>()
                            .with_context(|| format!("bad --shard auto:N count '{n}'"))?,
                    ),
                    None => None,
                },
                None => None,
            };
            if let Some(requested) = auto {
                let out = args
                    .opt("--out")?
                    .map(PathBuf::from)
                    .unwrap_or_else(|| PathBuf::from("results/sweep.jsonl"));
                args.finish()?;
                return run_supervised(
                    &PathBuf::from(cfg_path),
                    requested,
                    workers_cli,
                    retry_budget,
                    heartbeat,
                    fault_spec,
                    fault_attempts,
                    storage_uri,
                    resume,
                    dry_run,
                    &out,
                );
            }
            for (flag, given) in [
                ("--retry-budget", retry_budget.is_some()),
                ("--heartbeat-timeout", heartbeat.is_some()),
                ("--fault-attempts", fault_attempts.is_some()),
            ] {
                anyhow::ensure!(
                    !given,
                    "{flag} only applies to the supervisor (--shard auto[:N])"
                );
            }
            let shard = shard_raw
                .map(|s| odl_har::coordinator::ShardSpec::parse(&s))
                .transpose()?
                .unwrap_or(odl_har::coordinator::ShardSpec::WHOLE);
            // deterministic chaos for one process: parse the spec and
            // rebind it to the shard actually being run
            let faults = fault_spec
                .map(|s| odl_har::util::faults::FaultPlan::parse(&s))
                .transpose()?
                .map(|p| p.for_shard(shard.index))
                .unwrap_or_default();
            // shards must not share the unsharded default path — two
            // shard runs without --out would silently clobber each other
            let out = args.opt("--out")?.map(PathBuf::from).unwrap_or_else(|| {
                if shard.of > 1 {
                    PathBuf::from(format!(
                        "results/sweep.shard{}of{}.jsonl",
                        shard.index, shard.of
                    ))
                } else {
                    PathBuf::from("results/sweep.jsonl")
                }
            });
            args.finish()?;
            let mut spec = config::sweep_from_file(&PathBuf::from(cfg_path))?;
            // --storage beats [storage] uri beats no backend; retries and
            // backoff always come from the TOML section
            let mut stcfg = config::storage_from_file(&PathBuf::from(cfg_path))?;
            if storage_uri.is_some() {
                stcfg.uri = storage_uri;
            }
            let storage = odl_har::storage::Storage::open(&stcfg, &faults)?;
            if let Some(w) = workers_cli {
                spec.workers = w;
            }
            // 0 = auto, resolved once at startup
            spec.workers = odl_har::util::auto_workers(spec.workers);
            let plan = spec.plan();
            println!(
                "sweep: {} cells ({} seeds x {} thetas x {} edge counts x {} detectors x {} n_hiddens x {} loss probs x {} teacher errors), {} workers",
                plan.cells.len(),
                spec.seeds.len(),
                spec.thetas.len(),
                spec.edge_counts.len(),
                spec.detectors.len(),
                spec.n_hiddens.len(),
                spec.loss_probs.len(),
                spec.teacher_errors.len(),
                spec.workers
            );
            let range = plan.shard_range(shard)?;
            if shard.of > 1 {
                println!(
                    "sweep: shard {}/{} owns cells [{}, {}) — {} of {}",
                    shard.index,
                    shard.of,
                    range.start,
                    range.end,
                    range.len(),
                    plan.cells.len()
                );
            }
            if dry_run {
                // a sharded dry run plans exactly the slice that shard
                // will execute (slice-local lifetimes + ledger)
                print_sweep_plan(&plan, range);
                return Ok(());
            }
            // the banner plan above is the one the engine runs — planned
            // entry points avoid re-enumerating a large grid
            let stats = if resume {
                let outcome = odl_har::coordinator::sweep::resume_shard_via_storage(
                    &spec,
                    &plan,
                    shard,
                    &out,
                    &faults,
                    storage.as_ref(),
                )?;
                if outcome.already_complete {
                    println!(
                        "sweep: {} already holds the complete slice ({} cells) — nothing to do",
                        out.display(),
                        outcome.skipped
                    );
                } else {
                    println!(
                        "sweep: resumed — {} completed cell(s) kept, {} run",
                        outcome.skipped, outcome.ran
                    );
                }
                outcome.stats
            } else {
                odl_har::coordinator::sweep::run_shard_via_storage(
                    &spec,
                    &plan,
                    shard,
                    &out,
                    &faults,
                    storage.as_ref(),
                )?
                .stats
            };
            println!(
                "sweep: done — {} cells, data fitted {} time(s) ({} hit(s)), pools shuffled {} time(s) ({} hit(s)), edge cores provisioned {} time(s) ({} hit(s))",
                stats.cells,
                stats.artifact_builds,
                stats.artifact_hits,
                stats.shuffle_builds,
                stats.shuffle_hits,
                stats.edge_builds,
                stats.edge_hits
            );
            println!("results: {}", out.display());
        }
        "merge" => {
            let cfg_path = require(
                args.opt("--config")?,
                "merge requires --config FILE (the sweep's config)",
            )?;
            let out = args
                .opt("--out")?
                .map(PathBuf::from)
                .unwrap_or_else(|| PathBuf::from("results/sweep.jsonl"));
            let storage_uri = args.opt("--storage")?;
            let positional = args.positional();
            // a stray flag must error like every other subcommand, not be
            // opened as a shard file
            if let Some(flag) = positional.iter().find(|a| a.starts_with("--")) {
                bail!("unrecognized argument '{flag}' (merge takes --config, --out, --storage, and shard files)");
            }
            let inputs: Vec<PathBuf> = positional.into_iter().map(PathBuf::from).collect();
            if inputs.is_empty() {
                eprintln!("{USAGE}");
                bail!("merge requires the shard files as positional arguments");
            }
            let spec = config::sweep_from_file(&PathBuf::from(cfg_path))?;
            let plan = spec.plan();
            let mut stcfg = config::storage_from_file(&PathBuf::from(cfg_path))?;
            if storage_uri.is_some() {
                stcfg.uri = storage_uri;
            }
            let storage = odl_har::storage::Storage::open(
                &stcfg,
                &odl_har::util::faults::FaultPlan::default(),
            )?;
            // absent shard files are hydrated from storage before the
            // merge; the merged stream is published back afterwards
            let outcome = odl_har::coordinator::sweep::merge_via_storage(
                &plan,
                &inputs,
                &out,
                storage.as_ref(),
            )?;
            println!(
                "merge: {} shard file(s) -> {} cells, byte-identical to a single-process run",
                outcome.shards, outcome.cells
            );
            println!("results: {}", out.display());
        }
        "serve" => {
            let cfg_path = require(args.opt("--config")?, "serve requires --config FILE")?;
            let bind = args.opt("--bind")?;
            let snapshot = args.opt("--snapshot")?;
            let max_clients = args.opt_usize_opt("--max-clients")?;
            let workers = args.opt_usize_opt("--workers")?;
            let fault_spec = args.opt("--inject-faults")?;
            let storage_uri = args.opt("--storage")?;
            args.finish()?;
            let mut cfg = config::serve_from_file(&PathBuf::from(cfg_path))?;
            if let Some(b) = bind {
                cfg.bind = b;
            }
            if let Some(s) = snapshot {
                cfg.snapshot = Some(PathBuf::from(s));
            }
            if storage_uri.is_some() {
                // --storage beats [storage] uri; snapshots publish/restore
                // through the backend instead of the bare snapshot path
                cfg.storage.uri = storage_uri;
            }
            if let Some(m) = max_clients {
                anyhow::ensure!(m >= 1, "--max-clients must be >= 1");
                cfg.max_clients = m;
            }
            if let Some(w) = workers {
                // 0 = one shard worker per available core
                cfg.workers = w;
            }
            // serve_with binds the server end (#1) itself; pass the raw plan
            let faults = fault_spec
                .map(|s| odl_har::util::faults::FaultPlan::parse(&s))
                .transpose()?
                .unwrap_or_default();
            let summary = odl_har::coordinator::serve::serve_with(&cfg, &faults, |addr| {
                // the ready line is the port-handoff contract: tests and
                // scripts block on it, so it must be flushed immediately
                println!("serve: listening on {addr}");
                use std::io::Write as _;
                let _ = std::io::stdout().flush();
            })?;
            println!("{}", summary.to_json().to_string());
        }
        "loadgen" => {
            let addr = require(args.opt("--connect")?, "loadgen requires --connect ADDR")?;
            let cfg_path =
                require(args.opt("--config")?, "loadgen requires --config FILE")?;
            let client = args.opt("--client")?.unwrap_or_else(|| "edge-0".into());
            let events = args.opt_usize("--events", 64)?;
            let retry_budget = args.opt_usize_opt("--retry-budget")?;
            let backoff_base = args.opt_u64_opt("--backoff-base-ms")?;
            let backoff_cap = args.opt_u64_opt("--backoff-cap-ms")?;
            let reply_timeout = args.opt_u64_opt("--reply-timeout-ms")?;
            let batch = args.opt_usize_opt("--batch")?;
            let send_shutdown = args.flag("--shutdown");
            let summary_out = args.opt("--summary-out")?;
            let fault_spec = args.opt("--inject-faults")?;
            args.finish()?;
            // the client must derive its event stream from the *same*
            // scenario the server provisioned from — one config file,
            // read on both ends
            let scfg = config::serve_from_file(&PathBuf::from(cfg_path))?;
            let mut lcfg = odl_har::coordinator::serve::LoadgenConfig {
                addr,
                client,
                events,
                seed: scfg.seed,
                data_seed: scfg.data_seed(),
                synth: scfg.synth.clone(),
                send_shutdown,
                ..Default::default()
            };
            if let Some(rb) = retry_budget {
                lcfg.retry_budget = u32::try_from(rb).context("bad --retry-budget value")?;
            }
            if let Some(b) = backoff_base {
                anyhow::ensure!(b >= 1, "--backoff-base-ms must be >= 1");
                lcfg.backoff_base_ms = b;
            }
            if let Some(c) = backoff_cap {
                lcfg.backoff_cap_ms = c;
            }
            if let Some(t) = reply_timeout {
                anyhow::ensure!(t >= 1, "--reply-timeout-ms must be >= 1");
                lcfg.reply_timeout_ms = t;
            }
            if let Some(k) = batch {
                anyhow::ensure!(k >= 1, "--batch must be >= 1");
                // both ends read the same config file, so the server's
                // frame cap is known here — clamp instead of looping on
                // 'batch exceeds max_batch' errors
                lcfg.batch = k.min(scfg.max_batch.max(1));
            }
            if let Some(spec) = fault_spec {
                // loadgen() rebinds to the client end (#2) internally
                lcfg.faults = odl_har::util::faults::FaultPlan::parse(&spec)?;
            }
            let summary = odl_har::coordinator::serve::loadgen(&lcfg)?;
            let line = summary.to_json().to_string();
            if let Some(p) = summary_out {
                std::fs::write(&p, format!("{line}\n"))
                    .with_context(|| format!("writing {p}"))?;
            }
            println!("{line}");
        }
        "artifacts-check" => {
            args.finish()?;
            let rt = odl_har::runtime::Runtime::open_default()?;
            let mut names: Vec<String> =
                rt.manifest.artifacts.keys().cloned().collect();
            names.sort();
            for name in &names {
                let exe = rt.load(name)?;
                println!("OK {name} ({} args)", exe.meta.arg_shapes.len());
            }
            println!("{} artifacts compiled successfully", names.len());
        }
        "--help" | "-h" | "help" => print_help(),
        other => {
            // usage goes to stderr on misuse — stdout stays parseable
            eprintln!("{USAGE}");
            bail!("unknown subcommand '{other}'");
        }
    }
    Ok(())
}

/// `odl-har sweep --shard auto[:N]`: resolve the shard count and worker
/// split, build the supervisor config (CLI beats `[supervise]` TOML
/// beats defaults), and drive every shard to completion — relaunching
/// crashed/hung children onto `--resume` — before auto-merging into
/// `out`. Exits 0 complete / 2 degraded / 3 failed (see
/// `coordinator::supervise`).
#[allow(clippy::too_many_arguments)]
fn run_supervised(
    cfg_path: &PathBuf,
    requested_shards: usize,
    workers_cli: Option<usize>,
    retry_budget: Option<usize>,
    heartbeat: Option<f64>,
    fault_spec: Option<String>,
    fault_attempts: Option<usize>,
    storage_uri: Option<String>,
    _resume: bool, // supervision always resumes; the flag is harmless
    dry_run: bool,
    out: &PathBuf,
) -> Result<()> {
    use odl_har::coordinator::supervise::{
        shard_out_paths, supervise, ProcessLauncher, SuperviseStatus,
    };
    use odl_har::storage::{key_for_path, push_from_file, Storage};

    let mut spec = config::sweep_from_file(cfg_path)?;
    if let Some(w) = workers_cli {
        spec.workers = w;
    }
    let total_workers = odl_har::util::auto_workers(spec.workers);
    spec.workers = total_workers;
    let plan = spec.plan();
    anyhow::ensure!(
        !plan.cells.is_empty(),
        "--shard auto needs a non-empty grid"
    );

    let mut scfg = config::supervise_from_file(cfg_path)?;
    // CLI count beats the TOML one; 0 means one shard per worker. Never
    // more shards than cells (or workers).
    let requested = if requested_shards > 0 {
        requested_shards
    } else {
        scfg.shards
    };
    let n = if requested == 0 { total_workers } else { requested }
        .min(plan.cells.len())
        .max(1);
    scfg.shards = n;
    scfg.workers_per_shard = (total_workers / n).max(1);
    if let Some(rb) = retry_budget {
        scfg.retry_budget = rb;
    }
    if let Some(hb) = heartbeat {
        scfg.heartbeat_timeout_s = hb;
    }
    scfg.fault_spec = fault_spec;
    if let Some(fa) = fault_attempts {
        scfg.fault_attempts = fa;
    }

    // --storage beats [storage] uri beats no backend. The supervisor's
    // own probes and the final merged publish run fault-free; children
    // re-derive the fault plan (storage lanes included) from the
    // forwarded spec.
    let mut stcfg = config::storage_from_file(cfg_path)?;
    if storage_uri.is_some() {
        stcfg.uri = storage_uri;
    }
    let storage = Storage::open(&stcfg, &odl_har::util::faults::FaultPlan::default())?;

    let ranges = plan.shard_ranges(n);
    println!(
        "sweep: supervising {} shard(s) x {} worker(s) over {} cells (cost-weighted cuts)",
        n,
        scfg.workers_per_shard,
        plan.cells.len()
    );
    // With a *local* backend the spool IS the object: re-root the shard
    // spools into the storage root so children's publishes are no-op
    // same-target skips and the supervisor's heartbeat probes go through
    // the trait. Remote backends keep local spools (children upload
    // copies) and the supervisor probes the filesystem directly.
    let paths: Vec<PathBuf> = {
        let base = shard_out_paths(out, n);
        match storage.as_ref().filter(|s| s.is_local()) {
            Some(st) => base
                .iter()
                .map(|p| {
                    let key = key_for_path(p)?;
                    Ok(st.local_object_path(&key).expect("local backend has a root"))
                })
                .collect::<Result<_>>()?,
            None => base,
        }
    };
    for (r, p) in ranges.iter().zip(&paths) {
        let cost: u64 = (r.start..r.end).map(|i| plan.cell_cost(i)).sum();
        println!(
            "  cells [{}, {}) cost {} -> {}",
            r.start,
            r.end,
            cost,
            p.display()
        );
    }
    if dry_run {
        println!("dry run: plan only — no children launched");
        return Ok(());
    }
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let launcher = ProcessLauncher {
        exe: std::env::current_exe().context("resolving the odl-har binary path")?,
        config_path: cfg_path.clone(),
        storage_uri: stcfg.uri.clone(),
    };
    // storage-routed heartbeat probes only make sense where the object
    // tracks the live spool — the local backend, where spool == object
    let outcome = supervise(
        &plan,
        &scfg,
        &launcher,
        &paths,
        Some(out),
        storage.as_ref().filter(|s| s.is_local()),
    )?;
    for r in &outcome.shards {
        let state = if r.quarantined {
            "QUARANTINED"
        } else {
            "complete"
        };
        match &r.last_error {
            Some(e) => println!(
                "shard {}/{}: {} after {} attempt(s) (last error: {e})",
                r.index, n, state, r.attempts
            ),
            None => println!(
                "shard {}/{}: {} after {} attempt(s)",
                r.index, n, state, r.attempts
            ),
        }
    }
    match outcome.status {
        SuperviseStatus::Complete => {
            let m = outcome.merged.expect("complete status implies a merge");
            println!(
                "merge: {} shard file(s) -> {} cells, byte-identical to a single-process run",
                m.shards, m.cells
            );
            if let Some(st) = &storage {
                // publish the merged stream too, so a remote consumer can
                // `merge --storage` (or just `get`) without the host
                let key = key_for_path(out)?;
                if push_from_file(st, out, &key)? {
                    println!("storage: published '{key}' to the {} backend", st.backend_name());
                }
            }
            println!("results: {}", out.display());
            Ok(())
        }
        status => {
            if let Some(e) = &outcome.merge_error {
                eprintln!("merge failed: {e}");
            }
            eprintln!(
                "sweep: {} — merge skipped; rerun `sweep --shard auto` to resume the \
                 unfinished shard(s)",
                match status {
                    SuperviseStatus::Degraded => "degraded (some shards quarantined)",
                    _ => "failed",
                }
            );
            std::process::exit(status.exit_code());
        }
    }
}

/// `odl-har sweep --dry-run`: the enumerated grid, each cell's memo
/// build/hit role, and the artifact/shuffle/edge-core lifetimes (build at
/// first use, drop after last use) — without running a single cell.
fn print_sweep_plan(plan: &odl_har::coordinator::SweepPlan, range: std::ops::Range<usize>) {
    if range.len() == plan.cells.len() {
        println!("dry run: plan only — no cells will run");
    } else {
        println!(
            "dry run: plan only — no cells will run (shard slice: cells [{}, {}))",
            range.start, range.end
        );
    }
    // Slice-local lifetimes: the engine restricts remaining-use counts to
    // the cells it actually runs, so a shard builds at the slice's first
    // use and drops at the slice's last use — a sharded dry run must show
    // exactly what that shard will do, not the whole grid's lifetimes.
    // One source of truth: the same helper range_stats derives from.
    let lt = plan.slice_lifetimes(range.clone());
    let (art, shf, estates) = (&lt.artifacts, &lt.shuffles, &lt.edge_states);
    for (cell, _) in &plan.cells[range.clone()] {
        let (slot, shuf, est) = plan.cell_slots[cell.index];
        let s = &plan.artifacts[slot].shuffles[shuf];
        let e = &s.edge_states[est];
        let al = art[&slot];
        let sl = shf[&(slot, shuf)];
        let (el, _) = estates[&(slot, shuf, est)];
        let mut notes = Vec::new();
        if al.first == cell.index {
            notes.push(format!("build artifact a{slot}"));
        }
        if sl.first == cell.index {
            notes.push(format!("shuffle a{slot}/seed {}", s.seed));
        }
        if plan.memo_edge_state && el.first == cell.index {
            notes.push(format!(
                "provision edge cores a{slot}/seed {}/h{}",
                s.seed, e.n_hidden
            ));
        }
        if plan.memo_edge_state && el.last == cell.index {
            notes.push(format!(
                "drop edge cores a{slot}/seed {}/h{}",
                s.seed, e.n_hidden
            ));
        }
        if sl.last == cell.index {
            notes.push(format!("drop shuffle a{slot}/seed {}", s.seed));
        }
        if al.last == cell.index {
            notes.push(format!("drop artifact a{slot}"));
        }
        let theta = match cell.theta {
            Some(t) => format!("{t}"),
            None => "auto".into(),
        };
        println!(
            "  cell {:>4}: seed {} theta {} edges {} detector {} n_hidden {} loss {} teacher_err {}{}",
            cell.index,
            cell.seed,
            theta,
            cell.n_edges,
            cell.detector.name(),
            cell.n_hidden,
            cell.loss_prob,
            cell.teacher_error,
            if notes.is_empty() {
                String::new()
            } else {
                format!("  [{}]", notes.join(", "))
            }
        );
    }
    // the ledger a run over exactly this slice will report in its trailer
    let stats = plan.range_stats(range);
    println!(
        "memo plan: {} artifact build(s) + {} hit(s), {} shuffle build(s) + {} hit(s), {} edge core(s) + {} hit(s){}",
        stats.artifact_builds,
        stats.artifact_hits,
        stats.shuffle_builds,
        stats.shuffle_hits,
        stats.edge_builds,
        stats.edge_hits,
        if plan.memo_edge_state {
            ""
        } else {
            " (edge-state memo off)"
        }
    );
    for (slot, al) in art {
        let a = &plan.artifacts[*slot];
        println!(
            "  artifact a{slot} (data_key {:016x}): build at cell {}, {} use(s), drop after cell {}",
            a.key, al.first, al.uses, al.last
        );
        for ((_, shuf), sl) in shf.range((*slot, 0)..(*slot, usize::MAX)) {
            let s = &a.shuffles[*shuf];
            println!(
                "    shuffle seed {}: build at cell {}, {} use(s), drop after cell {}",
                s.seed, sl.first, sl.uses, sl.last
            );
            // with the memo off no shared core set ever exists — listing
            // build/drop points for it would contradict the ledger line
            if plan.memo_edge_state {
                for ((_, _, est), (el, max_edges)) in
                    estates.range((*slot, *shuf, 0)..(*slot, *shuf, usize::MAX))
                {
                    let e = &s.edge_states[*est];
                    println!(
                        "      edge cores n_hidden {}: up to {} core(s) from cell {}, {} lend(s), drop after cell {}",
                        e.n_hidden, max_edges, el.first, el.uses, el.last
                    );
                }
            }
        }
    }
}

/// One usage block, two exits: `help` prints it to stdout; misuse
/// (unknown subcommand, missing required argument) prints it to stderr
/// so stdout stays machine-parseable. `tests/cli_contract.rs` pins this.
const USAGE: &str =
        "odl-har — tiny supervised ODL core with auto data pruning (paper reproduction)\n\
         \n\
         subcommands:\n\
           table1                         SRAM size model (Table 1, exact)\n\
           table2 [--trials N]            params + accuracy vs SOTA (Table 2)\n\
           table3 [--trials N]            accuracy before/after drift (Table 3)\n\
           table4 [--area] [--ablate-divider]   core latency/power (Table 4, Fig 5)\n\
           fig1   [--out DIR]             per-class PCA projections (Figure 1)\n\
           fig3   [--trials N] [--metric p1p2|el2n] [--out DIR]   pruning sweep (Figure 3)\n\
           fig4   [--trials N] [--out DIR]      training-mode power (Figure 4)\n\
           run    --config FILE           custom experiment from TOML\n\
           fleet  [--config FILE] [--workers N] [--metrics full|aggregate] [--threaded]\n\
                                          multi-edge fleet simulation\n\
                                          (--workers shards provisioning + event loop; 0 = auto;\n\
                                           same report bit for bit for any count; --metrics\n\
                                           aggregate keeps O(1) sketched totals instead of\n\
                                           per-edge rows — same trajectories, less memory)\n\
           sweep  --config FILE [--workers N] [--out FILE] [--resume] [--dry-run] [--shard I/N]\n\
                  [--shard auto[:N] [--retry-budget K] [--heartbeat-timeout SECS]\n\
                   [--fault-attempts K]] [--inject-faults SPEC] [--storage DIR|URI]\n\
                                          memoized, resumable scenario-grid sweep (TOML-declared\n\
                                          seeds x thetas x edge counts x detectors x n_hiddens x\n\
                                          loss_probs x teacher_errors; artifacts fitted once per\n\
                                          data config, per-edge cores shared across cells that\n\
                                          differ only in fleet size, all built lazily and dropped\n\
                                          at last use; --resume keeps an interrupted file's\n\
                                          completed cells and finishes it byte-identical to an\n\
                                          uninterrupted run; --dry-run prints the grid + memo\n\
                                          plan without running; --shard I/N runs the I-th of N\n\
                                          disjoint cost-weighted grid slices for process-level\n\
                                          fan-out — 1/1 is byte-identical to no --shard at all;\n\
                                          --shard auto[:N] self-heals: one child per shard,\n\
                                          heartbeat-watched, crashed/hung children relaunched\n\
                                          onto --resume with exponential backoff, quarantined\n\
                                          after K retries, auto-merged on completion (exit 0\n\
                                          complete / 2 degraded / 3 failed; [supervise] TOML\n\
                                          section sets the defaults); --inject-faults SPEC\n\
                                          replays a deterministic fault schedule for chaos\n\
                                          testing — see rust/RELIABILITY.md; --storage publishes\n\
                                          each completed shard (and the supervised merge) to a\n\
                                          ResultStorage backend — a directory, or remote://DIR\n\
                                          with the remote-storage feature — and --resume\n\
                                          hydrates an absent spool from it; [storage] TOML\n\
                                          section sets uri/retries)\n\
           merge  --config FILE [--out FILE] [--storage DIR|URI] SHARD_FILE...\n\
                                          recombine a complete --shard file set into one results\n\
                                          file byte-identical to a single-process sweep (headers\n\
                                          validated against the config's grid, rows re-interleaved\n\
                                          in cell order, stats trailer recomputed from the plan;\n\
                                          --storage pulls shard files absent locally from the\n\
                                          backend and publishes the merged stream back)\n\
           serve  --config FILE [--bind ADDR] [--snapshot FILE] [--max-clients N]\n\
                  [--workers N] [--inject-faults SPEC] [--storage DIR|URI]\n\
                                          fault-tolerant teacher/label service over TCP (JSONL\n\
                                          protocol): per-client OS-ELM + auto-pruning state,\n\
                                          a fixed shard worker pool driving all admitted\n\
                                          connections (--workers threads; 0 = auto), admission\n\
                                          cap with structured busy, bounded queues, read/idle\n\
                                          deadlines, exactly-once in-order events (single or\n\
                                          batched frames), graceful drain to a crash-consistent\n\
                                          snapshot that a restart restores byte-identically;\n\
                                          --storage routes the snapshot through a ResultStorage\n\
                                          backend ([serve]/[storage] TOML sections set the\n\
                                          knobs; see rust/RELIABILITY.md)\n\
           loadgen --connect ADDR --config FILE [--client NAME] [--events N]\n\
                  [--batch K] [--retry-budget K] [--backoff-base-ms MS]\n\
                  [--backoff-cap-ms MS] [--reply-timeout-ms MS] [--shutdown]\n\
                  [--summary-out FILE] [--inject-faults SPEC]\n\
                                          deterministic edge client: replays a seeded event\n\
                                          stream against serve, survives outages with capped\n\
                                          exponential backoff + seeded jitter, buffers offline\n\
                                          and replays on reconnect; --batch K packs K events\n\
                                          per wire frame (clamped to the server's max_batch);\n\
                                          --shutdown drains the server after the last ack\n\
           artifacts-check                compile every PJRT artifact";

fn print_help() {
    println!("{USAGE}");
}
