//! The local-directory backend — and the home of the coordinator's
//! atomic-publish primitives.
//!
//! `sync_writer` / `sync_parent_dir` / `temp_sibling` moved here from
//! `coordinator/sweep.rs` unchanged (sweep re-exports them), so every
//! publish in the repo — sweep shard streams, merged outputs, serve
//! snapshots, and now [`LocalDir::put_atomic`] — shares one recipe:
//! write a `.tmp` sibling, fsync the file, rename over the
//! destination, fsync the directory. Readers see the old object or the
//! new one, whole, never a prefix.

use super::{gate_op, validate_key, ObjectMeta, ResultStorage, SResult, StorageError, StorageWrite};
use crate::util::faults::{FaultKind, FaultPlan};
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Flush a results writer and fsync the file so a subsequent rename
/// publishes fully durable bytes.
pub(crate) fn sync_writer(out: std::io::BufWriter<std::fs::File>, path: &Path) -> Result<()> {
    let file = out
        .into_inner()
        .map_err(|e| anyhow::anyhow!("flushing {}: {}", path.display(), e.error()))?;
    file.sync_all()
        .with_context(|| format!("fsyncing {}", path.display()))?;
    Ok(())
}

/// Fsync the directory containing `path` so a just-renamed file's
/// directory entry survives a crash. No-op off unix.
pub(crate) fn sync_parent_dir(path: &Path) -> Result<()> {
    #[cfg(unix)]
    {
        let dir = match path.parent() {
            Some(d) if !d.as_os_str().is_empty() => d,
            _ => Path::new("."),
        };
        std::fs::File::open(dir)
            .and_then(|d| d.sync_all())
            .with_context(|| format!("fsyncing directory {}", dir.display()))?;
    }
    #[cfg(not(unix))]
    let _ = path;
    Ok(())
}

/// The staging sibling a publish writes before renaming onto `path`.
pub(crate) fn temp_sibling(path: &Path) -> PathBuf {
    path.with_file_name(match path.file_name() {
        Some(name) => format!("{}.tmp", name.to_string_lossy()),
        None => ".tmp".to_string(),
    })
}

/// Write `bytes` to `dest` through the full atomic recipe: staged
/// `.tmp` sibling, fsync, rename, directory fsync.
pub(crate) fn write_file_atomic(dest: &Path, bytes: &[u8]) -> Result<()> {
    if let Some(parent) = dest.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    let tmp = temp_sibling(dest);
    let file =
        std::fs::File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
    let mut out = std::io::BufWriter::new(file);
    out.write_all(bytes)
        .with_context(|| format!("writing {}", tmp.display()))?;
    sync_writer(out, &tmp)?;
    std::fs::rename(&tmp, dest)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), dest.display()))?;
    sync_parent_dir(dest)?;
    Ok(())
}

/// Whether two paths name the same file target, without requiring
/// either to exist: lexical equality first, else compare canonicalized
/// parents + file names (the file itself may not exist yet).
pub(crate) fn same_target(a: &Path, b: &Path) -> bool {
    if a == b {
        return true;
    }
    let resolve = |p: &Path| -> Option<(PathBuf, std::ffi::OsString)> {
        let name = p.file_name()?.to_os_string();
        let parent = match p.parent() {
            Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
            _ => PathBuf::from("."),
        };
        Some((std::fs::canonicalize(&parent).ok()?, name))
    };
    match (resolve(a), resolve(b)) {
        (Some(ra), Some(rb)) => ra == rb,
        _ => false,
    }
}

/// Keys are relative paths under a root directory; `put_atomic` is the
/// fsync'd temp-file + rename recipe. With a non-noop [`FaultPlan`],
/// each backend operation consumes one fault-lane slot so chaos specs
/// (`sioerr@N` / `stear@N` / `sdelay@N`) can target individual ops.
pub struct LocalDir {
    root: PathBuf,
    faults: FaultPlan,
    ops: AtomicUsize,
}

impl LocalDir {
    pub fn new(root: &Path) -> LocalDir {
        LocalDir::with_faults(root, FaultPlan::default())
    }

    pub fn with_faults(root: &Path, faults: FaultPlan) -> LocalDir {
        LocalDir {
            root: root.to_path_buf(),
            faults,
            ops: AtomicUsize::new(0),
        }
    }

    fn next_op(&self) -> usize {
        self.ops.fetch_add(1, Ordering::Relaxed)
    }

    fn object_path(&self, key: &str) -> SResult<PathBuf> {
        validate_key(key)?;
        Ok(self.root.join(key))
    }
}

/// An in-flight [`LocalDir`] upload: bytes stream into the `.tmp`
/// sibling; `commit` fsyncs and renames it over the destination.
struct LocalWrite {
    tmp: PathBuf,
    dest: PathBuf,
    out: Option<std::io::BufWriter<std::fs::File>>,
    /// Fault drawn when the upload opened, applied at commit — a torn
    /// publish tears the *staged* bytes, exactly like a crashed writer.
    commit_fault: Option<FaultKind>,
}

impl Write for LocalWrite {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self.out.as_mut() {
            Some(out) => out.write(buf),
            None => Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                "upload already closed",
            )),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self.out.as_mut() {
            Some(out) => out.flush(),
            None => Ok(()),
        }
    }
}

impl StorageWrite for LocalWrite {
    fn commit(mut self: Box<Self>) -> SResult<()> {
        let Some(out) = self.out.take() else {
            return Err(StorageError::Permanent("upload already closed".into()));
        };
        match self.commit_fault {
            None => {}
            Some(FaultKind::StorageDelay) => {
                std::thread::sleep(std::time::Duration::from_millis(super::STORAGE_DELAY_MS));
            }
            Some(FaultKind::StorageTear) => {
                // tear the staged bytes in half and fail the commit: the
                // torn `.tmp` stays on disk (crash realism) but the
                // destination key is untouched
                let file = out.into_inner().map_err(|e| {
                    StorageError::Transient(format!("flushing {}: {}", self.tmp.display(), e.error()))
                })?;
                let torn = file
                    .metadata()
                    .map(|m| m.len() / 2)
                    .map_err(|e| StorageError::Transient(format!("injected tear stat: {e}")))?;
                file.set_len(torn)
                    .map_err(|e| StorageError::Transient(format!("injected tear truncate: {e}")))?;
                return Err(StorageError::Transient(format!(
                    "injected StorageTear: staged upload for {} torn at {torn} bytes",
                    self.dest.display()
                )));
            }
            Some(kind) => {
                let _ = std::fs::remove_file(&self.tmp);
                return Err(StorageError::Transient(format!(
                    "injected {kind:?} committing {}",
                    self.dest.display()
                )));
            }
        }
        sync_writer(out, &self.tmp).map_err(|e| StorageError::Transient(format!("{e:#}")))?;
        std::fs::rename(&self.tmp, &self.dest).map_err(|e| {
            StorageError::Transient(format!(
                "renaming {} -> {}: {e}",
                self.tmp.display(),
                self.dest.display()
            ))
        })?;
        sync_parent_dir(&self.dest).map_err(|e| StorageError::Transient(format!("{e:#}")))?;
        Ok(())
    }

    fn abort(mut self: Box<Self>) {
        self.out.take();
        let _ = std::fs::remove_file(&self.tmp);
    }
}

impl Drop for LocalWrite {
    fn drop(&mut self) {
        // dropped without commit: discard the staging file
        if self.out.take().is_some() {
            let _ = std::fs::remove_file(&self.tmp);
        }
    }
}

impl ResultStorage for LocalDir {
    fn backend(&self) -> &'static str {
        "local-dir"
    }

    fn put_atomic(&self, key: &str) -> SResult<Box<dyn StorageWrite>> {
        let dest = self.object_path(key)?;
        let op = self.next_op();
        let commit_fault = match self.faults.storage_fault(op) {
            Some(FaultKind::StorageIoErr) => {
                return Err(StorageError::Transient(format!(
                    "injected StorageIoErr at storage op {op} (put '{key}')"
                )))
            }
            other => other,
        };
        if let Some(parent) = dest.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(|e| {
                    StorageError::Transient(format!("creating {}: {e}", parent.display()))
                })?;
            }
        }
        let tmp = temp_sibling(&dest);
        let file = std::fs::File::create(&tmp)
            .map_err(|e| StorageError::Transient(format!("creating {}: {e}", tmp.display())))?;
        Ok(Box::new(LocalWrite {
            tmp,
            dest,
            out: Some(std::io::BufWriter::new(file)),
            commit_fault,
        }))
    }

    fn get(&self, key: &str) -> SResult<Box<dyn Read + Send>> {
        let path = self.object_path(key)?;
        gate_op(&self.faults, self.next_op(), &format!("get '{key}'"))?;
        match std::fs::File::open(&path) {
            Ok(f) => Ok(Box::new(f)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                Err(StorageError::NotFound(key.to_string()))
            }
            Err(e) => Err(StorageError::Transient(format!(
                "opening {}: {e}",
                path.display()
            ))),
        }
    }

    fn list(&self, prefix: &str) -> SResult<Vec<ObjectMeta>> {
        gate_op(&self.faults, self.next_op(), &format!("list '{prefix}'"))?;
        let mut out = Vec::new();
        let mut stack = vec![self.root.clone()];
        while let Some(dir) = stack.pop() {
            let entries = match std::fs::read_dir(&dir) {
                Ok(e) => e,
                Err(e) if e.kind() == std::io::ErrorKind::NotFound && dir == self.root => {
                    return Ok(out) // an absent root is just an empty store
                }
                Err(e) => {
                    return Err(StorageError::Transient(format!(
                        "listing {}: {e}",
                        dir.display()
                    )))
                }
            };
            for entry in entries {
                let entry = entry
                    .map_err(|e| StorageError::Transient(format!("listing {}: {e}", dir.display())))?;
                let path = entry.path();
                let meta = entry.metadata().map_err(|e| {
                    StorageError::Transient(format!("stat {}: {e}", path.display()))
                })?;
                if meta.is_dir() {
                    stack.push(path);
                    continue;
                }
                let Ok(rel) = path.strip_prefix(&self.root) else {
                    continue;
                };
                let key: Vec<String> = rel
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy().into_owned())
                    .collect();
                let key = key.join("/");
                // staging files are not objects
                if key.ends_with(".tmp") {
                    continue;
                }
                if key.starts_with(prefix) {
                    out.push(ObjectMeta { key, len: meta.len() });
                }
            }
        }
        out.sort_by(|a, b| a.key.cmp(&b.key));
        Ok(out)
    }

    fn delete(&self, key: &str) -> SResult<()> {
        let path = self.object_path(key)?;
        gate_op(&self.faults, self.next_op(), &format!("delete '{key}'"))?;
        match std::fs::remove_file(&path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                Err(StorageError::NotFound(key.to_string()))
            }
            Err(e) => Err(StorageError::Transient(format!(
                "removing {}: {e}",
                path.display()
            ))),
        }
    }

    fn stat(&self, key: &str) -> SResult<Option<u64>> {
        let path = self.object_path(key)?;
        gate_op(&self.faults, self.next_op(), &format!("stat '{key}'"))?;
        match std::fs::metadata(&path) {
            Ok(m) if m.is_dir() => Err(StorageError::Permanent(format!(
                "storage key '{key}' names a directory"
            ))),
            Ok(m) => Ok(Some(m.len())),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(StorageError::Transient(format!(
                "stat {}: {e}",
                path.display()
            ))),
        }
    }
}
