//! `RemoteStub` — an S3-shaped object store simulated on the local
//! filesystem (the offline vendor set has no HTTP stack), behind the
//! `remote-storage` cargo feature.
//!
//! The shape mirrors how real object stores behave, and how neon's
//! `s3_bucket`/`wal_backup` pairing consumes them:
//!
//! * **Uploads are invisible until complete.** Bytes stream into a
//!   numbered part file under `uploads/`, a separate namespace from
//!   `objects/`; only a committed upload is fsynced and renamed into
//!   `objects/<key>`. `get`/`stat`/`list` never observe a part file, so
//!   a torn or abandoned upload can never be read back as a half
//!   object — the property the whole retry policy leans on.
//! * **Every operation pays latency.** `latency_ms` (default
//!   [`DEFAULT_LATENCY_MS`]) sleeps on each call, so anything that
//!   chats with storage in a hot loop shows up in the chaos suites as
//!   wall-clock, the way a real remote would make it show up.
//! * **Failures are injected per operation.** The shared storage fault
//!   lane (`sioerr@N` / `stear@N` / `sdelay@N`) drives this backend
//!   exactly like [`super::LocalDir`], with `stear` tearing the staged
//!   part file mid-upload.

use super::{gate_op, validate_key, ObjectMeta, ResultStorage, SResult, StorageError, StorageWrite};
use crate::util::faults::{FaultKind, FaultPlan};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

/// Per-operation simulated round-trip latency.
pub const DEFAULT_LATENCY_MS: u64 = 2;

/// The filesystem-simulated remote object store.
pub struct RemoteStub {
    root: PathBuf,
    faults: FaultPlan,
    ops: AtomicUsize,
    uploads: AtomicUsize,
    latency_ms: u64,
}

impl RemoteStub {
    pub fn new(dir: &str) -> RemoteStub {
        RemoteStub::with_faults(dir, FaultPlan::default())
    }

    pub fn with_faults(dir: &str, faults: FaultPlan) -> RemoteStub {
        RemoteStub {
            root: PathBuf::from(dir),
            faults,
            ops: AtomicUsize::new(0),
            uploads: AtomicUsize::new(0),
            latency_ms: DEFAULT_LATENCY_MS,
        }
    }

    /// Override the per-operation latency (tests use 0 to stay fast).
    pub fn with_latency_ms(mut self, ms: u64) -> RemoteStub {
        self.latency_ms = ms;
        self
    }

    fn objects(&self) -> PathBuf {
        self.root.join("objects")
    }

    fn object_path(&self, key: &str) -> SResult<PathBuf> {
        validate_key(key)?;
        Ok(self.objects().join(key))
    }

    fn round_trip(&self) {
        if self.latency_ms > 0 {
            std::thread::sleep(Duration::from_millis(self.latency_ms));
        }
    }

    fn next_op(&self) -> usize {
        self.ops.fetch_add(1, Ordering::Relaxed)
    }
}

/// An in-flight multipart-style upload: bytes stream into a part file
/// under `uploads/`; only `commit` moves them into the object namespace.
struct RemoteWrite {
    part: PathBuf,
    dest: PathBuf,
    out: Option<std::io::BufWriter<std::fs::File>>,
    commit_fault: Option<FaultKind>,
    latency_ms: u64,
}

impl Write for RemoteWrite {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self.out.as_mut() {
            Some(out) => out.write(buf),
            None => Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                "upload already closed",
            )),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self.out.as_mut() {
            Some(out) => out.flush(),
            None => Ok(()),
        }
    }
}

impl StorageWrite for RemoteWrite {
    fn commit(mut self: Box<Self>) -> SResult<()> {
        let Some(out) = self.out.take() else {
            return Err(StorageError::Permanent("upload already closed".into()));
        };
        if self.latency_ms > 0 {
            std::thread::sleep(Duration::from_millis(self.latency_ms));
        }
        match self.commit_fault {
            None => {}
            Some(FaultKind::StorageDelay) => {
                std::thread::sleep(Duration::from_millis(super::STORAGE_DELAY_MS));
            }
            Some(FaultKind::StorageTear) => {
                // the "connection" died mid-upload: the part file is torn
                // and abandoned, the object namespace untouched
                let file = out.into_inner().map_err(|e| {
                    StorageError::Transient(format!("flushing {}: {}", self.part.display(), e.error()))
                })?;
                let torn = file
                    .metadata()
                    .map(|m| m.len() / 2)
                    .map_err(|e| StorageError::Transient(format!("injected tear stat: {e}")))?;
                file.set_len(torn)
                    .map_err(|e| StorageError::Transient(format!("injected tear truncate: {e}")))?;
                return Err(StorageError::Transient(format!(
                    "injected StorageTear: upload for {} torn at {torn} bytes",
                    self.dest.display()
                )));
            }
            Some(kind) => {
                let _ = std::fs::remove_file(&self.part);
                return Err(StorageError::Transient(format!(
                    "injected {kind:?} committing {}",
                    self.dest.display()
                )));
            }
        }
        super::local::sync_writer(out, &self.part)
            .map_err(|e| StorageError::Transient(format!("{e:#}")))?;
        if let Some(parent) = self.dest.parent() {
            std::fs::create_dir_all(parent).map_err(|e| {
                StorageError::Transient(format!("creating {}: {e}", parent.display()))
            })?;
        }
        std::fs::rename(&self.part, &self.dest).map_err(|e| {
            StorageError::Transient(format!(
                "completing upload {} -> {}: {e}",
                self.part.display(),
                self.dest.display()
            ))
        })?;
        super::local::sync_parent_dir(&self.dest)
            .map_err(|e| StorageError::Transient(format!("{e:#}")))?;
        Ok(())
    }

    fn abort(mut self: Box<Self>) {
        self.out.take();
        let _ = std::fs::remove_file(&self.part);
    }
}

impl Drop for RemoteWrite {
    fn drop(&mut self) {
        if self.out.take().is_some() {
            let _ = std::fs::remove_file(&self.part);
        }
    }
}

impl ResultStorage for RemoteStub {
    fn backend(&self) -> &'static str {
        "remote-stub"
    }

    fn put_atomic(&self, key: &str) -> SResult<Box<dyn StorageWrite>> {
        let dest = self.object_path(key)?;
        self.round_trip();
        let op = self.next_op();
        let commit_fault = match self.faults.storage_fault(op) {
            Some(FaultKind::StorageIoErr) => {
                return Err(StorageError::Transient(format!(
                    "injected StorageIoErr at storage op {op} (put '{key}')"
                )))
            }
            other => other,
        };
        let uploads = self.root.join("uploads");
        std::fs::create_dir_all(&uploads).map_err(|e| {
            StorageError::Transient(format!("creating {}: {e}", uploads.display()))
        })?;
        let part = uploads.join(format!(
            "upload-{}.part",
            self.uploads.fetch_add(1, Ordering::Relaxed)
        ));
        let file = std::fs::File::create(&part)
            .map_err(|e| StorageError::Transient(format!("creating {}: {e}", part.display())))?;
        Ok(Box::new(RemoteWrite {
            part,
            dest,
            out: Some(std::io::BufWriter::new(file)),
            commit_fault,
            latency_ms: self.latency_ms,
        }))
    }

    fn get(&self, key: &str) -> SResult<Box<dyn Read + Send>> {
        let path = self.object_path(key)?;
        self.round_trip();
        gate_op(&self.faults, self.next_op(), &format!("get '{key}'"))?;
        match std::fs::File::open(&path) {
            Ok(f) => Ok(Box::new(f)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                Err(StorageError::NotFound(key.to_string()))
            }
            Err(e) => Err(StorageError::Transient(format!(
                "opening {}: {e}",
                path.display()
            ))),
        }
    }

    fn list(&self, prefix: &str) -> SResult<Vec<ObjectMeta>> {
        self.round_trip();
        gate_op(&self.faults, self.next_op(), &format!("list '{prefix}'"))?;
        let objects = self.objects();
        let mut out = Vec::new();
        let mut stack = vec![objects.clone()];
        while let Some(dir) = stack.pop() {
            let entries = match std::fs::read_dir(&dir) {
                Ok(e) => e,
                Err(e) if e.kind() == std::io::ErrorKind::NotFound && dir == objects => {
                    return Ok(out)
                }
                Err(e) => {
                    return Err(StorageError::Transient(format!(
                        "listing {}: {e}",
                        dir.display()
                    )))
                }
            };
            for entry in entries {
                let entry = entry
                    .map_err(|e| StorageError::Transient(format!("listing {}: {e}", dir.display())))?;
                let path = entry.path();
                let meta = entry.metadata().map_err(|e| {
                    StorageError::Transient(format!("stat {}: {e}", path.display()))
                })?;
                if meta.is_dir() {
                    stack.push(path);
                    continue;
                }
                let Ok(rel) = path.strip_prefix(&objects) else {
                    continue;
                };
                let key: Vec<String> = rel
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy().into_owned())
                    .collect();
                let key = key.join("/");
                if key.starts_with(prefix) {
                    out.push(ObjectMeta { key, len: meta.len() });
                }
            }
        }
        out.sort_by(|a, b| a.key.cmp(&b.key));
        Ok(out)
    }

    fn delete(&self, key: &str) -> SResult<()> {
        let path = self.object_path(key)?;
        self.round_trip();
        gate_op(&self.faults, self.next_op(), &format!("delete '{key}'"))?;
        match std::fs::remove_file(&path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                Err(StorageError::NotFound(key.to_string()))
            }
            Err(e) => Err(StorageError::Transient(format!(
                "removing {}: {e}",
                path.display()
            ))),
        }
    }

    fn stat(&self, key: &str) -> SResult<Option<u64>> {
        let path = self.object_path(key)?;
        self.round_trip();
        gate_op(&self.faults, self.next_op(), &format!("stat '{key}'"))?;
        match std::fs::metadata(&path) {
            Ok(m) if m.is_dir() => Err(StorageError::Permanent(format!(
                "storage key '{key}' names a directory"
            ))),
            Ok(m) => Ok(Some(m.len())),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(StorageError::Transient(format!(
                "stat {}: {e}",
                path.display()
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Storage, StorageConfig};
    use super::*;

    fn tmp_root(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(name);
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn open(root: &Path, faults: &FaultPlan, cfg: &StorageConfig) -> Storage {
        Storage::open_uri(&format!("remote://{}", root.display()), cfg, faults).unwrap()
    }

    #[test]
    fn remote_uri_opens_the_stub_and_roundtrips() {
        let root = tmp_root("odl_har_remote_roundtrip");
        let st = open(&root, &FaultPlan::default(), &StorageConfig::default());
        assert_eq!(st.backend_name(), "remote-stub");
        assert!(!st.is_local(), "remote objects must not claim local paths");
        assert_eq!(st.local_object_path("a.jsonl"), None);
        st.put_bytes("a.jsonl", b"hello\n").unwrap();
        assert_eq!(st.get_bytes("a.jsonl").unwrap().unwrap(), b"hello\n");
        assert_eq!(st.stat("a.jsonl").unwrap(), Some(6));
        assert_eq!(st.list("").unwrap().len(), 1);
        st.delete("a.jsonl").unwrap();
        assert_eq!(st.get_bytes("a.jsonl").unwrap(), None);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn torn_and_abandoned_uploads_never_surface_as_objects() {
        let root = tmp_root("odl_har_remote_torn");
        // one-attempt budget: the torn upload is a hard error
        let cfg = StorageConfig {
            retry_limit: 1,
            backoff_base_ms: 1,
            backoff_cap_ms: 2,
            ..StorageConfig::default()
        };
        let faults = FaultPlan::parse("5:stear@0").unwrap();
        let st = open(&root, &faults, &cfg);
        assert!(st.put_bytes("t.jsonl", b"0123456789").is_err());
        // the torn part file exists under uploads/ but is not an object
        assert_eq!(st.get_bytes("t.jsonl").unwrap(), None);
        assert_eq!(st.stat("t.jsonl").unwrap(), None);
        assert!(st.list("").unwrap().is_empty());
        // an abandoned (dropped) streaming upload is equally invisible
        let stub = RemoteStub::new(root.to_str().unwrap()).with_latency_ms(0);
        let mut w = stub.put_atomic("t.jsonl").unwrap();
        use std::io::Write as _;
        w.write_all(b"half-").unwrap();
        drop(w);
        assert!(st.list("").unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn faulted_remote_publishes_converge_byte_identical_to_clean() {
        let chaos_root = tmp_root("odl_har_remote_chaos");
        let clean_root = tmp_root("odl_har_remote_clean");
        let payload: Vec<u8> = (0..2048u32).flat_map(|i| i.to_be_bytes()).collect();
        let cfg = StorageConfig {
            backoff_base_ms: 1,
            backoff_cap_ms: 4,
            ..StorageConfig::default()
        };
        let faults = FaultPlan::parse("9:stear@0,sioerr@1,sdelay@2").unwrap();
        let chaos = open(&chaos_root, &faults, &cfg);
        chaos.put_bytes("sweep.jsonl", &payload).unwrap();
        let clean = open(&clean_root, &FaultPlan::default(), &cfg);
        clean.put_bytes("sweep.jsonl", &payload).unwrap();
        assert_eq!(
            chaos.get_bytes("sweep.jsonl").unwrap().unwrap(),
            clean.get_bytes("sweep.jsonl").unwrap().unwrap(),
            "retried remote publish must converge on the fault-free bytes"
        );
        let _ = std::fs::remove_dir_all(&chaos_root);
        let _ = std::fs::remove_dir_all(&clean_root);
    }
}
