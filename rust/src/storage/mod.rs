//! Pluggable result storage — the multi-host enabler.
//!
//! Every durable artifact of the coordinator stack (sweep shard streams,
//! merged results, serve drain snapshots) was a process-local file path
//! until this layer existed. [`ResultStorage`] abstracts "a place shards
//! on different hosts can publish streams and `merge` can pull from":
//! opaque `/`-separated keys, streaming readers and writers, and one
//! hard invariant — **`put_atomic` makes all of an object's bytes
//! visible, or none of them**. Readers can never observe a torn publish,
//! which is what keeps the byte-identity contract (`tests/sweep_faults.rs`,
//! `tests/serve_faults.rs`) intact when the filesystem between writer
//! and reader becomes a network.
//!
//! Two backends:
//!
//! * [`LocalDir`] — keys map to paths under a root directory, and
//!   `put_atomic` is exactly the coordinator's long-standing fsync'd
//!   temp-file + rename recipe (`.tmp` sibling, fsync file, rename,
//!   fsync directory). The recipe's primitives live in [`local`] and are
//!   re-used verbatim by the sweep engine's own resume/merge publishes,
//!   so routing through the trait changes no bytes and no syscalls.
//! * `RemoteStub` (behind the `remote-storage` cargo feature) — an
//!   S3-shaped object store simulated on the local filesystem: uploads
//!   stage invisibly under a side directory and only a committed upload
//!   is renamed into the object namespace, mirroring how real object
//!   stores (and neon's `s3_bucket`/`wal_backup` pairing) expose only
//!   whole objects. Per-operation latency and failures are injectable.
//!
//! [`Storage`] wraps a backend with the **bounded-retry + exponential
//! backoff** policy (`[storage]` TOML / `--storage` CLI): transient
//! backend errors — the only kind fault injection produces — are retried
//! up to `retry_limit` attempts with doubling, capped backoff; permanent
//! errors and exhausted budgets surface to the caller. Fault injection
//! rides the same [`FaultPlan`] grammar as the rest of the chaos stack:
//! `sioerr@N` / `stear@N` / `sdelay@N` fire at the N-th storage
//! operation of a backend instance (see `util::faults`).

pub mod local;
#[cfg(feature = "remote-storage")]
pub mod remote;

use crate::util::faults::{FaultKind, FaultPlan};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::io::Read;
use std::path::{Path, PathBuf};
use std::time::Duration;

pub use local::LocalDir;
#[cfg(feature = "remote-storage")]
pub use remote::RemoteStub;

/// Simulated latency of one `sdelay`-faulted storage operation.
pub const STORAGE_DELAY_MS: u64 = 15;

/// Backend error, classified for the retry policy: only `Transient`
/// errors are retried; `NotFound` and `Permanent` surface immediately.
#[derive(Debug)]
pub enum StorageError {
    /// The key names no object.
    NotFound(String),
    /// The backend hiccuped (I/O error, torn upload, injected fault) —
    /// retrying the whole operation may heal it.
    Transient(String),
    /// Retrying cannot help (invalid key, misconfiguration).
    Permanent(String),
}

impl std::fmt::Display for StorageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StorageError::NotFound(key) => write!(f, "no such object '{key}'"),
            StorageError::Transient(msg) => write!(f, "transient backend error: {msg}"),
            StorageError::Permanent(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for StorageError {}

/// Backend-level result.
pub type SResult<T> = std::result::Result<T, StorageError>;

/// One listed object: its key and byte length.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ObjectMeta {
    pub key: String,
    pub len: u64,
}

/// A streaming upload in progress. Bytes written here are staged
/// invisibly; only [`StorageWrite::commit`] publishes them — atomically
/// and durably — under the writer's key. Dropping without committing
/// (or calling [`StorageWrite::abort`]) leaves the key untouched.
pub trait StorageWrite: std::io::Write + Send {
    /// Durably publish the staged bytes. All-or-nothing: a reader sees
    /// the whole object or the key's previous state, never a prefix.
    fn commit(self: Box<Self>) -> SResult<()>;
    /// Discard the staged bytes; the key is untouched.
    fn abort(self: Box<Self>);
}

/// The storage abstraction every coordinator publish/probe/pull goes
/// through. Keys are opaque `/`-separated relative names (see
/// [`validate_key`]); readers and writers stream. Implementations are
/// `Sync` so one handle can serve a worker pool.
pub trait ResultStorage: Send + Sync {
    /// Short backend label for diagnostics ("local-dir", "remote-stub").
    fn backend(&self) -> &'static str;
    /// Open a streaming, atomic upload for `key`.
    fn put_atomic(&self, key: &str) -> SResult<Box<dyn StorageWrite>>;
    /// Open a streaming reader over the object at `key`.
    fn get(&self, key: &str) -> SResult<Box<dyn Read + Send>>;
    /// All objects whose key starts with `prefix` (empty = everything),
    /// sorted by key. Staged/temporary uploads are never listed.
    fn list(&self, prefix: &str) -> SResult<Vec<ObjectMeta>>;
    /// Remove the object at `key` (`NotFound` if absent).
    fn delete(&self, key: &str) -> SResult<()>;
    /// Byte length of the object at `key`, `None` if absent. The default
    /// derives it from [`ResultStorage::list`]; backends override with a
    /// cheaper stat.
    fn stat(&self, key: &str) -> SResult<Option<u64>> {
        Ok(self
            .list(key)?
            .into_iter()
            .find(|m| m.key == key)
            .map(|m| m.len))
    }
}

/// Reject keys that could escape a backend's namespace or collide with
/// its staging convention: empty keys, absolute paths, `.`/`..`
/// components, backslashes, and the `.tmp` suffix (reserved for the
/// local backend's staging siblings) are all permanent errors.
pub fn validate_key(key: &str) -> SResult<()> {
    if key.is_empty() {
        return Err(StorageError::Permanent("empty storage key".into()));
    }
    if key.starts_with('/') || key.contains('\\') {
        return Err(StorageError::Permanent(format!(
            "storage key '{key}' must be a relative '/'-separated name"
        )));
    }
    for comp in key.split('/') {
        if comp.is_empty() || comp == "." || comp == ".." {
            return Err(StorageError::Permanent(format!(
                "storage key '{key}' has an empty or dot component"
            )));
        }
    }
    if key.ends_with(".tmp") {
        return Err(StorageError::Permanent(format!(
            "storage key '{key}' ends in '.tmp' — reserved for staging"
        )));
    }
    Ok(())
}

/// Apply the storage fault (if any) drawn for operation `op`: `sdelay`
/// sleeps [`STORAGE_DELAY_MS`] and proceeds; `sioerr` and `stear` both
/// surface as a transient backend error (on a download path a torn
/// transfer IS an I/O error from the caller's side — only `put_atomic`
/// commits give `stear` its distinct torn-staging semantics).
pub(crate) fn gate_op(faults: &FaultPlan, op: usize, what: &str) -> SResult<()> {
    match faults.storage_fault(op) {
        None => Ok(()),
        Some(FaultKind::StorageDelay) => {
            std::thread::sleep(Duration::from_millis(STORAGE_DELAY_MS));
            Ok(())
        }
        Some(kind) => Err(StorageError::Transient(format!(
            "injected {kind:?} at storage op {op} ({what})"
        ))),
    }
}

/// The `[storage]` TOML section / `--storage` CLI knobs. `uri: None`
/// means "no shared storage configured" — the coordinator then runs on
/// plain local files exactly as before (whose atomic publishes already
/// route through [`local`]'s primitives).
#[derive(Clone, Debug, PartialEq)]
pub struct StorageConfig {
    /// `DIR` (local-dir backend) or `remote://DIR` (the S3-shaped stub,
    /// `remote-storage` feature).
    pub uri: Option<String>,
    /// Total attempts per operation (first try + retries) on transient
    /// backend errors.
    pub retry_limit: usize,
    /// First retry delay; doubles per retry.
    pub backoff_base_ms: u64,
    /// Ceiling on the retry delay.
    pub backoff_cap_ms: u64,
}

impl Default for StorageConfig {
    fn default() -> Self {
        StorageConfig {
            uri: None,
            retry_limit: 4,
            backoff_base_ms: 25,
            backoff_cap_ms: 1000,
        }
    }
}

/// A backend plus the bounded-retry policy — the handle the coordinator
/// actually holds. All convenience operations retry transient errors
/// with exponential backoff; [`Storage::probe`] is the deliberate
/// exception (single attempt, never sleeps — it sits inside the
/// supervisor's poll loop).
pub struct Storage {
    backend: Box<dyn ResultStorage>,
    retry_limit: usize,
    backoff_base_ms: u64,
    backoff_cap_ms: u64,
    /// The object root when the backend is the local filesystem — lets
    /// callers recognize "this spool path IS the object" and skip
    /// copy-onto-itself publishes.
    local_root: Option<PathBuf>,
}

impl Storage {
    /// `None` when no URI is configured; otherwise the opened backend.
    pub fn open(cfg: &StorageConfig, faults: &FaultPlan) -> Result<Option<Storage>> {
        match &cfg.uri {
            None => Ok(None),
            Some(uri) => Ok(Some(Storage::open_uri(uri, cfg, faults)?)),
        }
    }

    /// Open `DIR` (local-dir) or `remote://DIR` (feature-gated stub).
    pub fn open_uri(uri: &str, cfg: &StorageConfig, faults: &FaultPlan) -> Result<Storage> {
        let uri = uri.trim();
        ensure!(!uri.is_empty(), "storage URI is empty");
        if let Some(rest) = uri.strip_prefix("remote://") {
            #[cfg(feature = "remote-storage")]
            {
                ensure!(!rest.is_empty(), "remote storage URI '{uri}' names no directory");
                return Ok(Storage::wrap(
                    Box::new(remote::RemoteStub::with_faults(rest, faults.clone())),
                    None,
                    cfg,
                ));
            }
            #[cfg(not(feature = "remote-storage"))]
            {
                let _ = rest;
                bail!(
                    "storage URI '{uri}' needs the `remote-storage` feature \
                     (rebuild with `--features remote-storage`)"
                );
            }
        }
        let root = PathBuf::from(uri);
        Ok(Storage::wrap(
            Box::new(LocalDir::with_faults(&root, faults.clone())),
            Some(root),
            cfg,
        ))
    }

    /// The default local backend over `root` — how callers without a
    /// configured URI still route their publishes through the trait.
    pub fn local_dir(root: &Path, cfg: &StorageConfig) -> Storage {
        Storage::wrap(
            Box::new(LocalDir::new(root)),
            Some(root.to_path_buf()),
            cfg,
        )
    }

    fn wrap(backend: Box<dyn ResultStorage>, local_root: Option<PathBuf>, cfg: &StorageConfig) -> Storage {
        Storage {
            backend,
            retry_limit: cfg.retry_limit.max(1),
            backoff_base_ms: cfg.backoff_base_ms.max(1),
            backoff_cap_ms: cfg.backoff_cap_ms.max(1),
            local_root,
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.backend()
    }

    /// Whether the backend is the local filesystem (its objects have
    /// direct paths).
    pub fn is_local(&self) -> bool {
        self.local_root.is_some()
    }

    /// The object's direct filesystem path, for local backends only.
    pub fn local_object_path(&self, key: &str) -> Option<PathBuf> {
        self.local_root.as_ref().map(|r| r.join(key))
    }

    fn retrying<T>(
        &self,
        what: &str,
        key: &str,
        mut op: impl FnMut() -> SResult<T>,
    ) -> Result<T> {
        let mut delay = self.backoff_base_ms;
        for attempt in 1..=self.retry_limit {
            match op() {
                Ok(v) => return Ok(v),
                Err(StorageError::Transient(msg)) if attempt < self.retry_limit => {
                    eprintln!(
                        "storage: {what} '{key}' on {}: {msg} \
                         (attempt {attempt}/{}) — backing off {delay}ms",
                        self.backend.backend(),
                        self.retry_limit,
                    );
                    std::thread::sleep(Duration::from_millis(delay));
                    delay = delay.saturating_mul(2).min(self.backoff_cap_ms);
                }
                Err(e) => {
                    return Err(anyhow!(
                        "storage: {what} '{key}' on {}: {e}",
                        self.backend.backend()
                    ))
                }
            }
        }
        unreachable!("the retry loop returns on its last attempt");
    }

    /// Atomically publish `bytes` under `key`, retrying the whole upload
    /// (fresh staging) on transient errors.
    pub fn put_bytes(&self, key: &str, bytes: &[u8]) -> Result<()> {
        self.retrying("put", key, || {
            let mut w = self.backend.put_atomic(key)?;
            if let Err(e) = w.write_all(bytes) {
                w.abort();
                return Err(StorageError::Transient(format!("staging write: {e}")));
            }
            w.commit()
        })
    }

    /// The object's bytes, `None` if absent.
    pub fn get_bytes(&self, key: &str) -> Result<Option<Vec<u8>>> {
        self.retrying("get", key, || {
            let mut r = match self.backend.get(key) {
                Ok(r) => r,
                Err(StorageError::NotFound(_)) => return Ok(None),
                Err(e) => return Err(e),
            };
            let mut buf = Vec::new();
            r.read_to_end(&mut buf)
                .map_err(|e| StorageError::Transient(format!("reading object: {e}")))?;
            Ok(Some(buf))
        })
    }

    /// Byte length of the object at `key`, with retries.
    pub fn stat(&self, key: &str) -> Result<Option<u64>> {
        self.retrying("stat", key, || self.backend.stat(key))
    }

    /// One non-blocking liveness probe — **no retry, no backoff** (it
    /// runs inside the supervisor's poll loop, which must never sleep).
    /// A backend error comes back as `Err` for the caller to classify:
    /// the heartbeat must treat it as "unknown", never as "no growth".
    pub fn probe(&self, key: &str) -> std::result::Result<Option<u64>, String> {
        self.backend.stat(key).map_err(|e| e.to_string())
    }

    /// Objects under `prefix`, sorted by key, with retries.
    pub fn list(&self, prefix: &str) -> Result<Vec<ObjectMeta>> {
        self.retrying("list", prefix, || self.backend.list(prefix))
    }

    /// Delete `key`; deleting an absent object is success (idempotent,
    /// like an object store's delete).
    pub fn delete(&self, key: &str) -> Result<()> {
        self.retrying("delete", key, || match self.backend.delete(key) {
            Err(StorageError::NotFound(_)) => Ok(()),
            other => other,
        })
    }
}

/// Pull `key` into the local file `dest` using the same fsync'd
/// temp-file + rename recipe every coordinator publish uses, so a crash
/// mid-pull never leaves a torn spool. Returns `false` without touching
/// `dest` when the object is absent — or when `dest` *is* the object
/// (local backend, same path): the spool is already the published copy.
pub fn pull_to_file(storage: &Storage, key: &str, dest: &Path) -> Result<bool> {
    if storage
        .local_object_path(key)
        .is_some_and(|obj| local::same_target(&obj, dest))
    {
        return Ok(false);
    }
    let Some(bytes) = storage.get_bytes(key)? else {
        return Ok(false);
    };
    local::write_file_atomic(dest, &bytes)
        .with_context(|| format!("landing storage object '{key}' at {}", dest.display()))?;
    Ok(true)
}

/// Publish the local file `src` under `key`. Returns `false` when `src`
/// already *is* the object (local backend, same path) — the stream was
/// written in place and another copy would be pure churn.
pub fn push_from_file(storage: &Storage, src: &Path, key: &str) -> Result<bool> {
    if storage
        .local_object_path(key)
        .is_some_and(|obj| local::same_target(&obj, src))
    {
        return Ok(false);
    }
    let bytes =
        std::fs::read(src).with_context(|| format!("reading {} for publish", src.display()))?;
    storage.put_bytes(key, &bytes)?;
    Ok(true)
}

/// The storage key a results path publishes under: its file name. Shard
/// spools, merged outputs, and snapshots all carry their identity in the
/// name (`sweep.shard2of4.jsonl`), so the flat key space is collision-free
/// per study directory.
pub fn key_for_path(path: &Path) -> Result<String> {
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .with_context(|| format!("{} has no UTF-8 file name to key storage by", path.display()))?;
    validate_key(name).map_err(|e| anyhow!("{e}"))?;
    Ok(name.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn tmp_root(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(name);
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn plain(root: &Path) -> Storage {
        Storage::local_dir(root, &StorageConfig::default())
    }

    #[test]
    fn keys_are_validated_as_safe_relative_names() {
        for ok in ["a", "a.jsonl", "runs/2026/sweep.jsonl", "a-b_c.1"] {
            assert!(validate_key(ok).is_ok(), "'{ok}' should be a valid key");
        }
        for bad in ["", "/abs", "a//b", "a/../b", ".", "..", "a\\b", "stage.tmp"] {
            assert!(validate_key(bad).is_err(), "'{bad}' should be rejected");
        }
    }

    #[test]
    fn local_roundtrip_put_get_list_stat_delete() {
        let root = tmp_root("odl_har_storage_roundtrip");
        let st = plain(&root);
        assert_eq!(st.get_bytes("a.jsonl").unwrap(), None);
        assert_eq!(st.stat("a.jsonl").unwrap(), None);
        st.put_bytes("a.jsonl", b"hello\n").unwrap();
        st.put_bytes("runs/b.jsonl", b"nested\n").unwrap();
        assert_eq!(st.get_bytes("a.jsonl").unwrap().unwrap(), b"hello\n");
        assert_eq!(st.stat("a.jsonl").unwrap(), Some(6));
        let listed = st.list("").unwrap();
        assert_eq!(
            listed,
            vec![
                ObjectMeta { key: "a.jsonl".into(), len: 6 },
                ObjectMeta { key: "runs/b.jsonl".into(), len: 7 },
            ]
        );
        assert_eq!(st.list("runs/").unwrap().len(), 1);
        st.delete("a.jsonl").unwrap();
        assert_eq!(st.get_bytes("a.jsonl").unwrap(), None);
        // idempotent delete: an absent object is success
        st.delete("a.jsonl").unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn staged_uploads_are_invisible_until_commit() {
        let root = tmp_root("odl_har_storage_staging");
        let st = plain(&root);
        st.put_bytes("seen.jsonl", b"old").unwrap();
        let backend = LocalDir::new(&root);
        let mut w = backend.put_atomic("seen.jsonl").unwrap();
        use std::io::Write as _;
        w.write_all(b"new bytes, much longer").unwrap();
        w.flush().unwrap();
        // mid-upload: readers still see the previous object whole
        assert_eq!(st.get_bytes("seen.jsonl").unwrap().unwrap(), b"old");
        assert_eq!(st.stat("seen.jsonl").unwrap(), Some(3));
        assert_eq!(st.list("").unwrap().len(), 1, "staging must not be listed");
        w.commit().unwrap();
        assert_eq!(
            st.get_bytes("seen.jsonl").unwrap().unwrap(),
            b"new bytes, much longer"
        );
        // aborted uploads leave the object untouched
        let mut w = backend.put_atomic("seen.jsonl").unwrap();
        w.write_all(b"doomed").unwrap();
        w.abort();
        assert_eq!(
            st.get_bytes("seen.jsonl").unwrap().unwrap(),
            b"new bytes, much longer"
        );
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn retried_publishes_converge_byte_identical_under_injected_faults() {
        let root = tmp_root("odl_har_storage_chaos");
        let clean_root = tmp_root("odl_har_storage_chaos_clean");
        let payload: Vec<u8> = (0..4096u32).flat_map(|i| i.to_le_bytes()).collect();
        // ops 0/1 fail (transient I/O error, torn upload), op 2 is only
        // delayed — the third attempt lands the full object
        let faults = FaultPlan::parse("5:sioerr@0,stear@1,sdelay@2").unwrap();
        let cfg = StorageConfig {
            backoff_base_ms: 1,
            backoff_cap_ms: 4,
            ..StorageConfig::default()
        };
        let st = Storage::open_uri(root.to_str().unwrap(), &cfg, &faults).unwrap();
        st.put_bytes("sweep.jsonl", &payload).unwrap();
        let clean = Storage::open_uri(clean_root.to_str().unwrap(), &cfg, &FaultPlan::default())
            .unwrap();
        clean.put_bytes("sweep.jsonl", &payload).unwrap();
        assert_eq!(
            st.get_bytes("sweep.jsonl").unwrap().unwrap(),
            clean.get_bytes("sweep.jsonl").unwrap().unwrap(),
            "a fault-retried publish must converge on the fault-free bytes"
        );
        // a torn upload must never become a visible half-object
        let torn_faults = FaultPlan::parse("5:stear@0").unwrap();
        let torn = Storage::open_uri(
            tmp_root("odl_har_storage_chaos_torn").to_str().unwrap(),
            &StorageConfig { retry_limit: 1, ..cfg.clone() },
            &torn_faults,
        )
        .unwrap();
        assert!(torn.put_bytes("t.jsonl", &payload).is_err());
        assert_eq!(torn.get_bytes("t.jsonl").unwrap(), None);
        assert!(torn.list("").unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&root);
        let _ = std::fs::remove_dir_all(&clean_root);
    }

    #[test]
    fn exhausted_retry_budget_surfaces_the_transient_error() {
        let root = tmp_root("odl_har_storage_budget");
        let faults = FaultPlan::parse("5:sioerr@0,sioerr@1").unwrap();
        let cfg = StorageConfig {
            retry_limit: 2,
            backoff_base_ms: 1,
            backoff_cap_ms: 2,
            ..StorageConfig::default()
        };
        let st = Storage::open_uri(root.to_str().unwrap(), &cfg, &faults).unwrap();
        let err = st.put_bytes("a.jsonl", b"x").unwrap_err();
        assert!(
            format!("{err:#}").contains("StorageIoErr"),
            "the exhausted budget must name the injected fault: {err:#}"
        );
        // with one more attempt in the budget the same schedule heals
        let st = Storage::open_uri(
            root.to_str().unwrap(),
            &StorageConfig { retry_limit: 3, ..cfg },
            &faults,
        )
        .unwrap();
        st.put_bytes("a.jsonl", b"x").unwrap();
        assert_eq!(st.get_bytes("a.jsonl").unwrap().unwrap(), b"x");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn probe_is_single_attempt_and_reports_errors_distinctly() {
        let root = tmp_root("odl_har_storage_probe");
        let faults = FaultPlan::parse("5:sioerr@1").unwrap();
        let st = Storage::open_uri(root.to_str().unwrap(), &StorageConfig::default(), &faults)
            .unwrap();
        st.put_bytes("a.jsonl", b"abc").unwrap(); // op 0
        let err = st.probe("a.jsonl").unwrap_err(); // op 1: injected, NOT retried
        assert!(err.contains("StorageIoErr"), "probe error must surface: {err}");
        assert_eq!(st.probe("a.jsonl").unwrap(), Some(3)); // op 2: clean
        assert_eq!(st.probe("missing").unwrap(), None);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn pull_and_push_skip_in_place_local_objects() {
        let root = tmp_root("odl_har_storage_inplace");
        std::fs::create_dir_all(&root).unwrap();
        let st = plain(&root);
        let spool = root.join("s.jsonl");
        std::fs::write(&spool, b"spooled\n").unwrap();
        // the spool IS the object: neither direction copies
        assert!(!push_from_file(&st, &spool, "s.jsonl").unwrap());
        assert!(!pull_to_file(&st, "s.jsonl", &spool).unwrap());
        assert_eq!(st.get_bytes("s.jsonl").unwrap().unwrap(), b"spooled\n");
        // a different destination really pulls
        let other = tmp_root("odl_har_storage_inplace_other").join("pulled.jsonl");
        assert!(pull_to_file(&st, "s.jsonl", &other).unwrap());
        assert_eq!(std::fs::read(&other).unwrap(), b"spooled\n");
        assert!(!pull_to_file(&st, "absent.jsonl", &other).unwrap());
        let _ = std::fs::remove_dir_all(&root);
        let _ = std::fs::remove_dir_all(other.parent().unwrap());
    }

    /// A scripted backend for exercising the retry wrapper without a
    /// filesystem: fails the first `fail_n` put attempts.
    struct Flaky {
        fail_n: usize,
        calls: AtomicUsize,
    }

    struct FlakySink;
    impl std::io::Write for FlakySink {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }
    impl StorageWrite for FlakySink {
        fn commit(self: Box<Self>) -> SResult<()> {
            Ok(())
        }
        fn abort(self: Box<Self>) {}
    }

    impl ResultStorage for Flaky {
        fn backend(&self) -> &'static str {
            "flaky"
        }
        fn put_atomic(&self, _key: &str) -> SResult<Box<dyn StorageWrite>> {
            let n = self.calls.fetch_add(1, Ordering::SeqCst);
            if n < self.fail_n {
                Err(StorageError::Transient(format!("scripted failure {n}")))
            } else {
                Ok(Box::new(FlakySink))
            }
        }
        fn get(&self, key: &str) -> SResult<Box<dyn Read + Send>> {
            Err(StorageError::NotFound(key.into()))
        }
        fn list(&self, _prefix: &str) -> SResult<Vec<ObjectMeta>> {
            Ok(Vec::new())
        }
        fn delete(&self, key: &str) -> SResult<()> {
            Err(StorageError::NotFound(key.into()))
        }
    }

    #[test]
    fn retry_policy_is_bounded_and_counts_attempts() {
        let cfg = StorageConfig {
            retry_limit: 3,
            backoff_base_ms: 1,
            backoff_cap_ms: 2,
            ..StorageConfig::default()
        };
        let ok = Storage {
            backend: Box::new(Flaky { fail_n: 2, calls: AtomicUsize::new(0) }),
            retry_limit: cfg.retry_limit,
            backoff_base_ms: cfg.backoff_base_ms,
            backoff_cap_ms: cfg.backoff_cap_ms,
            local_root: None,
        };
        ok.put_bytes("k", b"x").unwrap(); // 2 failures + 1 success = budget 3
        let exhausted = Storage {
            backend: Box::new(Flaky { fail_n: 3, calls: AtomicUsize::new(0) }),
            retry_limit: cfg.retry_limit,
            backoff_base_ms: cfg.backoff_base_ms,
            backoff_cap_ms: cfg.backoff_cap_ms,
            local_root: None,
        };
        assert!(exhausted.put_bytes("k", b"x").is_err());
    }
}
