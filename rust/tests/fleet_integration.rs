//! Integration: the full-dimension fleet simulation (561 features) with
//! pruning, lossy channel, energy accounting — the system-level story.

use odl_har::coordinator::fleet::{DetectorKind, Fleet, FleetConfig, Scenario};
use odl_har::coordinator::ChannelConfig;
use odl_har::data::SynthConfig;

fn scenario() -> Scenario {
    Scenario {
        n_edges: 4,
        n_hidden: 128,
        event_period_s: 1.0,
        horizon_s: 700.0,
        drift_at_s: 150.0,
        detector: DetectorKind::Oracle,
        fixed_theta: None,
        teacher_error: 0.0,
        channel: ChannelConfig {
            loss_prob: 0.05,
            max_retries: 2,
            ..Default::default()
        },
        synth: SynthConfig::default(),
        train_target: 400,
        ..Default::default()
    }
}

#[test]
fn fleet_full_scale_recovers_and_saves_power() {
    let auto = Fleet::new(FleetConfig {
        scenario: scenario(),
        seed: 9,
    })
    .unwrap()
    .run();
    let mut sc_full = scenario();
    sc_full.fixed_theta = Some(1.0);
    let full = Fleet::new(FleetConfig {
        scenario: sc_full,
        seed: 9,
    })
    .unwrap()
    .run();

    for (m_auto, m_full) in auto.per_edge.iter().zip(&full.per_edge) {
        // recovery: final rolling accuracy healthy on both
        let acc_auto = m_auto.accuracy_trace.last().unwrap().1;
        assert!(acc_auto > 0.75, "auto final acc {acc_auto}");
        // pruning cuts queries…
        assert!(
            m_auto.queries < m_full.queries,
            "auto {} vs full {}",
            m_auto.queries,
            m_full.queries
        );
    }
    // …and mean power
    assert!(
        auto.mean_edge_power_mw() < full.mean_edge_power_mw(),
        "auto {} mW vs full {} mW",
        auto.mean_edge_power_mw(),
        full.mean_edge_power_mw()
    );
    // the sleep floor bounds power from below
    assert!(auto.mean_edge_power_mw() > 1.33);
}

#[test]
fn noisy_teacher_disables_pruning() {
    // A correct safety property of the auto-θ controller: when the teacher
    // disagrees with the local model (here: 60 % wrong labels), the
    // mismatch rule keeps θ pinned at 1.0, so pruning never engages and
    // every training-mode event queries — the edge does not silently
    // trust its own (now unverifiable) confidence.
    let clean = Fleet::new(FleetConfig {
        scenario: scenario(),
        seed: 11,
    })
    .unwrap()
    .run();
    let mut sc = scenario();
    sc.teacher_error = 0.6;
    let noisy = Fleet::new(FleetConfig {
        scenario: sc,
        seed: 11,
    })
    .unwrap()
    .run();
    let queries = |r: &odl_har::coordinator::FleetReport| r.total_queries();
    assert!(
        queries(&noisy) as f64 > queries(&clean) as f64 * 1.4,
        "noisy teacher must suppress pruning: noisy {} vs clean {}",
        queries(&noisy),
        queries(&clean)
    );
    // and with a clean teacher, pruning must engage within the episode
    let total_events: u64 = clean.per_edge.iter().map(|m| m.queries + m.skips).sum();
    assert!(
        queries(&clean) < total_events,
        "clean run must skip some queries"
    );
}
