//! Property tests on the coordinator invariants (routing, accounting,
//! state machine), using the in-house property harness over randomized
//! scenarios.

use odl_har::coordinator::fleet::{DetectorKind, Fleet, FleetConfig, Scenario};
use odl_har::coordinator::ChannelConfig;
use odl_har::data::SynthConfig;
use odl_har::util::prop::{forall, gen};

fn random_scenario(rng: &mut odl_har::util::rng::Rng64) -> (Scenario, u64) {
    let sc = Scenario {
        n_edges: gen::usize_in(rng, 1, 5),
        n_hidden: 32,
        event_period_s: [0.5, 1.0, 2.0][rng.below(3)],
        horizon_s: gen::usize_in(rng, 120, 300) as f64,
        drift_at_s: gen::usize_in(rng, 30, 90) as f64,
        detector: if rng.bernoulli(0.5) {
            DetectorKind::Oracle
        } else {
            DetectorKind::Centroid
        },
        fixed_theta: if rng.bernoulli(0.5) {
            Some([0.08, 0.16, 0.32, 1.0][rng.below(4)])
        } else {
            None
        },
        teacher_error: [0.0, 0.0, 0.2][rng.below(3)],
        channel: ChannelConfig {
            loss_prob: [0.0, 0.1, 0.5][rng.below(3)],
            max_retries: rng.below(3) as u32,
            ..Default::default()
        },
        synth: SynthConfig {
            n_features: 40,
            n_classes: 4,
            n_subjects: 30,
            samples_per_cell: 8,
            proto_sigma: 1.1,
            ..Default::default()
        },
        train_target: gen::usize_in(rng, 50, 200),
        ..Default::default()
    };
    let seed = rng.next_u64();
    (sc, seed)
}

#[test]
fn fleet_accounting_invariants() {
    std::env::set_var("ODL_PROP_CASES", "8"); // fleet runs are not free
    forall("fleet-accounting", random_scenario, |(sc, seed)| {
        let report = Fleet::new(FleetConfig {
            scenario: sc.clone(),
            seed: *seed,
        })
        .unwrap()
        .run();

        let horizon = sc.horizon_s;
        for m in &report.per_edge {
            // 1. every event is exactly one of query/skip/predicting-mode
            if m.queries + m.skips > m.events {
                return false;
            }
            // 2. trained ≤ queries (training needs a delivered label)
            if m.trained > m.queries {
                return false;
            }
            // 3. state-time books cover the horizon
            let t: f64 = m.state_time_s.values().sum();
            if (t - horizon).abs() > 1.0 {
                return false;
            }
            // 4. power bounded below by SRAM retention, above by
            //    peak-state + one query per event
            let p = m.mean_power_mw(horizon);
            if !(1.33..=200.0).contains(&p) {
                return false;
            }
        }
        // 5. teacher served exactly the delivered queries
        let delivered: u64 = report.channel_attempts - report.channel_failures;
        if report.teacher_queries > delivered {
            return false;
        }
        // 6. lossless channel ⇒ attempts == deliveries
        if sc.channel.loss_prob == 0.0 && report.channel_failures != 0 {
            return false;
        }
        true
    });
}

#[test]
fn fleet_determinism_property() {
    std::env::set_var("ODL_PROP_CASES", "4");
    forall("fleet-determinism", random_scenario, |(sc, seed)| {
        let run = |s: &Scenario, seed: u64| {
            let r = Fleet::new(FleetConfig {
                scenario: s.clone(),
                seed,
            })
            .unwrap()
            .run();
            (
                r.total_queries(),
                r.channel_attempts,
                r.per_edge.iter().map(|m| m.trained).collect::<Vec<_>>(),
            )
        };
        run(sc, *seed) == run(sc, *seed)
    });
}

#[test]
fn pruner_ladder_always_on_ladder() {
    use odl_har::pruning::{AutoTheta, THETA_LADDER};
    forall(
        "theta-on-ladder",
        |rng| {
            let x = gen::usize_in(rng, 1, 20) as u32;
            let ops: Vec<bool> = (0..gen::usize_in(rng, 0, 200))
                .map(|_| rng.bernoulli(0.8))
                .collect();
            (x, ops)
        },
        |(x, ops)| {
            let mut a = AutoTheta::new(*x);
            for &success in ops {
                if success {
                    a.on_success();
                } else {
                    a.on_mismatch();
                }
                if !THETA_LADDER.contains(&a.theta()) {
                    return false;
                }
            }
            true
        },
    );
}
