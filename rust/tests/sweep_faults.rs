//! Chaos suite for the self-healing shard supervisor: drive real
//! `odl-har sweep --shard I/N` child processes through seeded
//! kill/torn-write/cell-panic/hang schedules (`--inject-faults`, see
//! `util::faults`) and assert the supervisor's auto-merged output is
//! **byte-identical** to an undisturbed single-process run — the
//! determinism contract extended to the failure domain. Also pins the
//! CLI exit-code contract: 0 complete / 2 degraded / 3 failed.

use odl_har::config;
use odl_har::coordinator::supervise::{
    shard_out_paths, supervise, ProcessLauncher, SuperviseStatus,
};
use odl_har::coordinator::sweep::{run_planned_to_file, SweepPlan};
use std::path::PathBuf;

/// A 4-cell grid (2 seeds x 2 loss probs) that a sweep finishes in
/// about a second — big enough for two shards with a real interior cut,
/// small enough to chaos-test many schedules. The `[supervise]` section
/// doubles as coverage for the TOML knobs.
const CONFIG: &str = r#"
[fleet]
n_edges = 2
n_hidden = 16
horizon_s = 30
drift_at_s = 12
train_target = 24
seed = 1
data_seed = 77
workers = 1

[data]
n_features = 24
n_classes = 3
samples_per_cell = 4

[sweep]
seeds = [1, 2]
thetas = ["auto"]
edge_counts = [2]
detectors = ["oracle"]
n_hiddens = [16]
loss_probs = [0.0, 0.2]
teacher_errors = [0.0]
workers = 1

[supervise]
retry_budget = 3
backoff_base_ms = 5
backoff_cap_ms = 20
poll_ms = 5
"#;

fn exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_odl-har"))
}

struct Setup {
    dir: PathBuf,
    cfg_path: PathBuf,
    plan: SweepPlan,
    clean: Vec<u8>,
}

fn setup(name: &str) -> Setup {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("grid.toml");
    std::fs::write(&cfg_path, CONFIG).unwrap();
    let mut spec = config::sweep_from_str(CONFIG).unwrap();
    spec.workers = 2; // worker counts never move an output byte
    let plan = spec.plan();
    let single = dir.join("single.jsonl");
    run_planned_to_file(&spec, &plan, &single).unwrap();
    let clean = std::fs::read(&single).unwrap();
    Setup {
        dir,
        cfg_path,
        plan,
        clean,
    }
}

#[test]
fn chaos_schedules_recover_to_byte_identical_merge() {
    let s = setup("odl_har_chaos_schedules_test");
    // one schedule per injected failure mode: a child SIGKILL mid-stream,
    // a torn trailer write, and a cell that defeats the in-pool retry
    let schedules = ["11:kill@2", "12:tear@3", "13:panic2@1"];
    for (si, sched) in schedules.iter().enumerate() {
        for &w in &[1usize, 2, 8] {
            let merged = s.dir.join(format!("merged_{si}_w{w}.jsonl"));
            let paths = shard_out_paths(&merged, 2);
            let mut scfg = config::supervise_from_str(CONFIG).unwrap();
            scfg.workers_per_shard = w;
            scfg.fault_spec = Some(sched.to_string());
            let launcher = ProcessLauncher {
                exe: exe(),
                config_path: s.cfg_path.clone(),
                storage_uri: None,
            };
            let out = supervise(&s.plan, &scfg, &launcher, &paths, Some(&merged), None).unwrap();
            assert_eq!(
                out.status,
                SuperviseStatus::Complete,
                "schedule {sched} x {w} workers must self-heal: {:?}",
                out.shards
            );
            assert!(
                out.shards.iter().any(|r| r.attempts > 1),
                "schedule {sched} should have forced at least one relaunch"
            );
            assert_eq!(
                std::fs::read(&merged).unwrap(),
                s.clean,
                "schedule {sched} x {w} workers: merged bytes diverged"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&s.dir);
}

#[test]
fn seeded_chaos_is_replayable_and_recovers() {
    let s = setup("odl_har_chaos_seeded_test");
    let mut attempts_seen = Vec::new();
    for round in 0..2 {
        let merged = s.dir.join(format!("merged_r{round}.jsonl"));
        let paths = shard_out_paths(&merged, 2);
        let mut scfg = config::supervise_from_str(CONFIG).unwrap();
        scfg.workers_per_shard = 2;
        // bare seed = fully seeded schedule drawn from stream_seed —
        // write faults and first-attempt cell panics, never hangs
        scfg.fault_spec = Some("1701".to_string());
        let launcher = ProcessLauncher {
            exe: exe(),
            config_path: s.cfg_path.clone(),
            storage_uri: None,
        };
        let out = supervise(&s.plan, &scfg, &launcher, &paths, Some(&merged), None).unwrap();
        assert_eq!(out.status, SuperviseStatus::Complete);
        assert_eq!(std::fs::read(&merged).unwrap(), s.clean);
        attempts_seen.push(out.shards.iter().map(|r| r.attempts).collect::<Vec<_>>());
    }
    assert_eq!(
        attempts_seen[0], attempts_seen[1],
        "the same fault seed must replay the same failure schedule"
    );
    let _ = std::fs::remove_dir_all(&s.dir);
}

#[test]
fn hung_child_process_is_sigkilled_and_recovered() {
    let s = setup("odl_har_chaos_hang_test");
    let merged = s.dir.join("merged.jsonl");
    let paths = shard_out_paths(&merged, 2);
    let mut scfg = config::supervise_from_str(CONFIG).unwrap();
    scfg.workers_per_shard = 1;
    // shard 2 wedges (flushes its durable prefix, then spins) — only the
    // byte-growth heartbeat can catch this
    scfg.fault_spec = Some("14:hang@2#2".to_string());
    scfg.heartbeat_timeout_s = 1.0;
    scfg.poll_ms = 50;
    let launcher = ProcessLauncher {
        exe: exe(),
        config_path: s.cfg_path.clone(),
        storage_uri: None,
    };
    let out = supervise(&s.plan, &scfg, &launcher, &paths, Some(&merged), None).unwrap();
    assert_eq!(out.status, SuperviseStatus::Complete, "{:?}", out.shards);
    assert!(out.shards[1].attempts >= 2, "the hung shard must relaunch");
    assert!(out.shards[1]
        .last_error
        .as_deref()
        .unwrap()
        .contains("no heartbeat"));
    assert_eq!(std::fs::read(&merged).unwrap(), s.clean);
    let _ = std::fs::remove_dir_all(&s.dir);
}

/// A fault that lands *after* a shard's stream is durably complete must
/// not burn the retry budget. Slot 3 on shard 2 (2 cells + trailer) is
/// "kill/hang between the trailer flush and process exit": with
/// `retry_budget = 0` a supervisor that retires the corpse instead of
/// reading the finished file quarantines the shard and exits degraded —
/// the false-hang/false-kill audit this test pins.
#[test]
fn faults_after_a_complete_stream_are_success_not_failures() {
    let s = setup("odl_har_chaos_postcomplete_test");
    for (i, sched) in ["15:kill@3#2", "15:hang@3#2"].iter().enumerate() {
        let merged = s.dir.join(format!("merged_{i}.jsonl"));
        let paths = shard_out_paths(&merged, 2);
        let mut scfg = config::supervise_from_str(CONFIG).unwrap();
        scfg.workers_per_shard = 1;
        // zero budget: a single false retire quarantines the shard
        scfg.retry_budget = 0;
        scfg.fault_spec = Some(sched.to_string());
        scfg.heartbeat_timeout_s = 1.0;
        scfg.poll_ms = 50;
        let launcher = ProcessLauncher {
            exe: exe(),
            config_path: s.cfg_path.clone(),
            storage_uri: None,
        };
        let out = supervise(&s.plan, &scfg, &launcher, &paths, Some(&merged), None).unwrap();
        assert_eq!(
            out.status,
            SuperviseStatus::Complete,
            "schedule {sched}: a fault after the trailer flush must read as \
             success, not burn the (zero) retry budget: {:?}",
            out.shards
        );
        assert_eq!(
            out.shards[1].attempts, 1,
            "schedule {sched}: the complete file must be recognized without a relaunch"
        );
        assert_eq!(
            std::fs::read(&merged).unwrap(),
            s.clean,
            "schedule {sched}: merged bytes diverged"
        );
    }
    let _ = std::fs::remove_dir_all(&s.dir);
}

#[test]
fn cli_exit_codes_distinguish_complete_degraded_failed() {
    let s = setup("odl_har_chaos_exitcode_test");
    let run = |extra: &[&str], out: &std::path::Path| -> i32 {
        let status = std::process::Command::new(exe())
            .arg("sweep")
            .arg("--config")
            .arg(&s.cfg_path)
            .arg("--shard")
            .arg("auto:2")
            .arg("--out")
            .arg(out)
            .args(extra)
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null())
            .status()
            .expect("spawning the supervisor CLI");
        status.code().expect("supervisor must exit, not die on a signal")
    };

    // complete (0): a mid-run kill is retried and auto-merged
    let merged = s.dir.join("merged_ok.jsonl");
    let code = run(&["--retry-budget", "3", "--inject-faults", "11:kill@2"], &merged);
    assert_eq!(code, 0);
    assert_eq!(std::fs::read(&merged).unwrap(), s.clean);

    // degraded (2): shard 2 tears forever with no retry budget; shard 1
    // completes — merge is skipped
    let merged = s.dir.join("merged_degraded.jsonl");
    let code = run(
        &[
            "--retry-budget",
            "0",
            "--fault-attempts",
            "9",
            "--inject-faults",
            "7:tear@1#2",
        ],
        &merged,
    );
    assert_eq!(code, 2);
    assert!(!merged.exists(), "a degraded study must not publish a merge");

    // failed (3): every shard tears forever
    let merged = s.dir.join("merged_failed.jsonl");
    let code = run(
        &[
            "--retry-budget",
            "0",
            "--fault-attempts",
            "9",
            "--inject-faults",
            "7:tear@1",
        ],
        &merged,
    );
    assert_eq!(code, 3);
    assert!(!merged.exists());

    // a degraded study resumes: rerunning with the fault cleared finishes
    // only the quarantined shard and publishes the byte-identical merge
    let merged = s.dir.join("merged_degraded.jsonl");
    let code = run(&["--retry-budget", "1"], &merged);
    assert_eq!(code, 0);
    assert_eq!(std::fs::read(&merged).unwrap(), s.clean);

    let _ = std::fs::remove_dir_all(&s.dir);
}

/// The multi-host shape end to end through the CLI: a supervised sweep
/// with `--storage` survives a mid-stream child kill (resume + relaunch),
/// publishes every shard and the merge into the backend, and a separate
/// `merge --storage` run on a host with *no local shard files* hydrates
/// them from the backend into the byte-identical results stream.
#[test]
fn storage_backed_sweep_survives_kills_and_remerges_from_the_backend() {
    let s = setup("odl_har_chaos_storage_test");
    let store = s.dir.join("store");
    let merged = s.dir.join("merged.jsonl");
    let status = std::process::Command::new(exe())
        .arg("sweep")
        .arg("--config")
        .arg(&s.cfg_path)
        .arg("--shard")
        .arg("auto:2")
        .arg("--retry-budget")
        .arg("3")
        .arg("--inject-faults")
        .arg("18:kill@2#1")
        .arg("--storage")
        .arg(&store)
        .arg("--out")
        .arg(&merged)
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .status()
        .expect("spawning the supervisor CLI");
    assert_eq!(status.code(), Some(0), "storage-backed supervised sweep must self-heal");
    assert_eq!(std::fs::read(&merged).unwrap(), s.clean);
    // the backend holds the shard objects (spool == object for the
    // local-dir backend) and the published merge
    for name in [
        "merged.shard1of2.jsonl",
        "merged.shard2of2.jsonl",
        "merged.jsonl",
    ] {
        assert_eq!(
            std::fs::read(store.join(name)).unwrap_or_default().is_empty(),
            false,
            "backend is missing object '{name}'"
        );
    }
    assert_eq!(std::fs::read(store.join("merged.jsonl")).unwrap(), s.clean);

    // "another host": no local shard files — merge hydrates them from
    // the backend by key and republishes the merged stream
    let pull = s.dir.join("pull");
    std::fs::create_dir_all(&pull).unwrap();
    let remerged = pull.join("remerged.jsonl");
    let status = std::process::Command::new(exe())
        .arg("merge")
        .arg("--config")
        .arg(&s.cfg_path)
        .arg("--storage")
        .arg(&store)
        .arg("--out")
        .arg(&remerged)
        .arg(pull.join("merged.shard1of2.jsonl"))
        .arg(pull.join("merged.shard2of2.jsonl"))
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .status()
        .expect("spawning the merge CLI");
    assert_eq!(status.code(), Some(0), "merge-from-storage must succeed");
    assert_eq!(
        std::fs::read(&remerged).unwrap(),
        s.clean,
        "merge pulled from storage diverged from the single-process bytes"
    );
    assert_eq!(std::fs::read(store.join("remerged.jsonl")).unwrap(), s.clean);
    let _ = std::fs::remove_dir_all(&s.dir);
}
