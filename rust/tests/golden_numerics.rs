//! Cross-language numerics: the golden vectors emitted by
//! `python/compile/aot.py` (from the jnp reference in `kernels/ref.py`)
//! must match the rust implementations bit-for-bit (PRNG) or to f32
//! round-off (float pipelines).

use odl_har::linalg::Mat;
use odl_har::odl::xorshift::{counter_alpha, Xorshift16};
use odl_har::odl::{AlphaKind, OsElm, OsElmConfig};
use odl_har::util::json::Json;
use odl_har::util::rng::Rng64;
use std::path::PathBuf;

fn goldens() -> Option<Json> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/golden/numerics.json");
    if !path.exists() {
        eprintln!("SKIP: goldens not built (run `make artifacts`)");
        return None;
    }
    Some(Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap())
}

fn arr_f32(j: &Json) -> Vec<f32> {
    j.as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect()
}

#[test]
fn xorshift_stream_bit_exact() {
    let Some(g) = goldens() else { return };
    let want: Vec<u16> = g
        .get("xorshift16_stream_seed1")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as u16)
        .collect();
    let mut s = Xorshift16::new(1);
    let got: Vec<u16> = (0..want.len()).map(|_| s.next_u16()).collect();
    assert_eq!(got, want, "sequential xorshift16 stream diverged from python");
}

#[test]
fn counter_alpha_bit_exact() {
    let Some(g) = goldens() else { return };
    let want = arr_f32(g.get("counter_alpha_seed9_16x8").unwrap());
    let got = counter_alpha(9, 16, 8, 1.0);
    assert_eq!(got, want, "counter-based alpha diverged from python");
}

#[test]
fn hidden_activations_match() {
    let Some(g) = goldens() else { return };
    let want = arr_f32(g.get("hidden_n561_N128_seed7").unwrap());
    // deterministic input from aot.py: (arange(561) % 17 - 8) / 8
    let x: Vec<f32> = (0..561).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
    let cfg = OsElmConfig {
        n_in: 561,
        n_hidden: 128,
        n_out: 6,
        alpha: AlphaKind::Hash,
        ..Default::default()
    };
    let model = OsElm::new(cfg, &mut Rng64::new(0), 7);
    let mut h = vec![0.0f32; 128];
    model.hidden(&x, &mut h);
    for (i, (a, b)) in h.iter().zip(&want).enumerate() {
        assert!(
            (a - b).abs() < 1e-5,
            "hidden[{i}]: rust {a} vs python {b}"
        );
    }
}

#[test]
fn train_step_matches() {
    let Some(g) = goldens() else { return };
    let t = g.get("train_step").unwrap();
    let nh = t.get("n_hidden").unwrap().as_usize().unwrap();
    let h = arr_f32(t.get("h").unwrap());
    let p_diag = t.get("p_diag").unwrap().as_f64().unwrap() as f32;
    let beta = arr_f32(t.get("beta").unwrap());
    let y_class = t.get("y_class").unwrap().as_usize().unwrap();
    let want_p = arr_f32(t.get("p_new").unwrap());
    let want_beta = arr_f32(t.get("beta_new").unwrap());

    // Rust-side rank-1 update on the same state (replicating the math the
    // OsElm hot path performs, but from the given H rather than from x).
    let m = 6usize;
    let mut p = Mat::zeros(nh, nh);
    for i in 0..nh {
        *p.at_mut(i, i) = p_diag;
    }
    let mut b = Mat::from_vec(nh, m, beta);
    let mut ph = vec![0.0f32; nh];
    for i in 0..nh {
        ph[i] = odl_har::linalg::mat::dot(p.row(i), &h);
    }
    let denom = 1.0 + odl_har::linalg::mat::dot(&h, &ph);
    let mut err = vec![0.0f32; m];
    for (j, e) in err.iter_mut().enumerate() {
        *e = if j == y_class { 1.0 } else { 0.0 };
    }
    for i in 0..nh {
        for j in 0..m {
            err[j] -= h[i] * b.at(i, j);
        }
    }
    for i in 0..nh {
        let s = ph[i] / denom;
        for j in 0..nh {
            *p.at_mut(i, j) -= s * ph[j];
        }
        for j in 0..m {
            *b.at_mut(i, j) += s * err[j];
        }
    }
    for (i, (a, w)) in p.data.iter().zip(&want_p).enumerate() {
        assert!((a - w).abs() < 1e-5, "P[{i}]: {a} vs {w}");
    }
    for (i, (a, w)) in b.data.iter().zip(&want_beta).enumerate() {
        assert!((a - w).abs() < 1e-5, "beta[{i}]: {a} vs {w}");
    }
}
