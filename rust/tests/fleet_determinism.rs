//! The parallel-engine contract: `Fleet::run_parallel(k)` must produce a
//! `FleetReport` **bitwise identical** to the sequential `Fleet::run` for
//! the same seed — across seeds, worker counts, detectors, a lossy
//! channel, a noisy teacher, and live evaluation windows (every RNG
//! stream the shards own gets exercised). Floats are compared by bit
//! pattern (`FleetReport::bitwise_eq`), not tolerance. Worker counts come
//! from the shared executor's canonical `util::parallel::WORKER_SWEEP`
//! (1/2/8), so this suite and the sweep-engine suite assert the same
//! sweep against the same `util::parallel` layer every call site now
//! routes through. The sweep engine's edge-state memo rides the same
//! contract: provisioned cores shared across cells that differ only in
//! `n_edges` must leave every report bit untouched (asserted here over
//! the same worker sweep, memo on vs off).

use odl_har::coordinator::fleet::{DetectorKind, Fleet, FleetConfig, Scenario};
use odl_har::coordinator::sweep::{run_sweep, SweepSpec};
use odl_har::coordinator::{ChannelConfig, FleetReport};
use odl_har::data::SynthConfig;
use odl_har::util::parallel::WORKER_SWEEP;

fn scenario(detector: DetectorKind) -> Scenario {
    Scenario {
        n_edges: 5,
        n_hidden: 32,
        event_period_s: 1.0,
        horizon_s: 260.0,
        drift_at_s: 60.0,
        detector,
        teacher_error: 0.15,
        channel: ChannelConfig {
            loss_prob: 0.25,
            max_retries: 1,
            ..Default::default()
        },
        train_target: 100,
        eval_period_s: 40.0,
        eval_samples: 24,
        synth: SynthConfig {
            n_features: 40,
            n_classes: 4,
            n_subjects: 30,
            samples_per_cell: 8,
            proto_sigma: 1.1,
            confuse_frac: 0.04,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn run(sc: &Scenario, seed: u64, workers: usize) -> FleetReport {
    let fleet = Fleet::new(FleetConfig {
        scenario: sc.clone(),
        seed,
    })
    .unwrap();
    if workers == 0 {
        fleet.run()
    } else {
        fleet.run_parallel(workers)
    }
}

#[test]
fn parallel_bitwise_identical_across_seeds_and_worker_counts() {
    let sc = scenario(DetectorKind::Oracle);
    for seed in [1u64, 7, 23] {
        let seq = run(&sc, seed, 0);
        for k in WORKER_SWEEP {
            let par = run(&sc, seed, k);
            assert!(
                seq.bitwise_eq(&par),
                "report diverged: seed {seed}, {k} workers"
            );
        }
    }
}

#[test]
fn parallel_bitwise_identical_with_centroid_detector() {
    // organic drift detection exercises the detector state machine per
    // shard instead of the scripted force at drift_at_s
    let sc = scenario(DetectorKind::Centroid);
    let seq = run(&sc, 5, 0);
    for &k in &WORKER_SWEEP[1..] {
        let par = run(&sc, 5, k);
        assert!(seq.bitwise_eq(&par), "centroid diverged at {k} workers");
    }
}

#[test]
fn worker_oversubscription_is_safe_and_identical() {
    // more workers than edges must clamp, not skew
    let sc = scenario(DetectorKind::Oracle);
    let seq = run(&sc, 13, 0);
    let par = run(&sc, 13, 64);
    assert!(seq.bitwise_eq(&par), "oversubscribed run diverged");
}

#[test]
fn eval_power_flag_preserves_parallel_determinism() {
    let mut sc = scenario(DetectorKind::Oracle);
    sc.eval_costs_power = true;
    let seq = run(&sc, 29, 0);
    let par = run(&sc, 29, 4);
    assert!(seq.bitwise_eq(&par), "eval_costs_power run diverged");
}

fn run_provisioned(sc: &Scenario, seed: u64, provision_workers: usize) -> FleetReport {
    Fleet::new_parallel(
        FleetConfig {
            scenario: sc.clone(),
            seed,
        },
        provision_workers,
    )
    .unwrap()
    .run_parallel(2)
}

#[test]
fn provisioning_workers_bitwise_identical_across_seeds_and_detectors() {
    // The construction contract: Fleet::new built with 1/2/8 provisioning
    // workers must yield bitwise-equal FleetReports after run_parallel,
    // across seeds and detectors (per-edge init_batch is a pure function
    // of the shared pool and the edge id — no worker partitioning may
    // leak into the numbers).
    for detector in [DetectorKind::Oracle, DetectorKind::Centroid] {
        let sc = scenario(detector);
        for seed in [3u64, 17] {
            let reference = run_provisioned(&sc, seed, WORKER_SWEEP[0]);
            for &workers in &WORKER_SWEEP[1..] {
                let sharded = run_provisioned(&sc, seed, workers);
                assert!(
                    reference.bitwise_eq(&sharded),
                    "provisioning diverged: {detector:?}, seed {seed}, {workers} workers"
                );
            }
        }
    }
}

#[test]
fn provisioning_worker_oversubscription_is_safe_and_identical() {
    // more provisioning workers than edges must clamp, not skew
    let sc = scenario(DetectorKind::Oracle);
    let reference = run_provisioned(&sc, 7, 1);
    let oversubscribed = run_provisioned(&sc, 7, 64);
    assert!(reference.bitwise_eq(&oversubscribed));
}

#[test]
fn provisioning_and_run_workers_compose_bitwise() {
    // sequential everything vs sharded construction + sharded event loop
    let sc = scenario(DetectorKind::Oracle);
    let sequential = run(&sc, 23, 0);
    let sharded = Fleet::new_parallel(
        FleetConfig {
            scenario: sc.clone(),
            seed: 23,
        },
        8,
    )
    .unwrap()
    .run_parallel(4);
    assert!(sequential.bitwise_eq(&sharded));
}

/// A sweep whose only moving axis is the fleet size — the edge-state
/// memo's home turf: every cell of a seed shares one provisioned-core
/// set. Lossy channel + noisy teacher + eval windows keep every RNG
/// stream hot; the shortened horizon keeps the grid affordable.
fn edge_memo_spec(workers: usize, memo: bool) -> SweepSpec {
    let mut base = scenario(DetectorKind::Oracle);
    base.n_edges = 2;
    base.horizon_s = 120.0;
    base.data_seed = Some(0xED6E);
    SweepSpec {
        seeds: vec![3, 17],
        thetas: vec![base.fixed_theta],
        edge_counts: WORKER_SWEEP.to_vec(),
        detectors: vec![base.detector],
        n_hiddens: vec![base.n_hidden],
        loss_probs: vec![base.channel.loss_prob],
        teacher_errors: vec![base.teacher_error],
        workers,
        record_pca: false,
        memo_edge_state: memo,
        base,
    }
}

#[test]
fn edge_state_memo_bitwise_invisible_across_worker_counts() {
    // The edge-state-memo contract: cells differing only in n_edges
    // share provisioned cores when the memo is on, and every FleetReport
    // must equal the memo-off run bit for bit, over the shared
    // WORKER_SWEEP — the memo (like every worker count) is a wall-clock
    // knob, never a numerics knob.
    let reference = run_sweep(&edge_memo_spec(1, false)).unwrap();
    assert_eq!(reference.stats.edge_hits, 0, "memo off must never hit");
    for &workers in &WORKER_SWEEP {
        for memo in [false, true] {
            if workers == 1 && !memo {
                continue; // that is the reference itself
            }
            let got = run_sweep(&edge_memo_spec(workers, memo)).unwrap();
            assert_eq!(reference.reports.len(), got.reports.len());
            for ((cell, a), (_, b)) in reference.reports.iter().zip(&got.reports) {
                assert!(
                    a.bitwise_eq(b),
                    "cell {} diverged (memo {memo}, {workers} workers)",
                    cell.index
                );
            }
        }
    }
    // and the memo genuinely engages: per seed, the largest fleet
    // (max(1, 2, 8) = 8) is built once and the smaller cells borrow —
    // 8 builds + (1 + 2) hits per seed over two seeds
    let memo_stats = run_sweep(&edge_memo_spec(1, true)).unwrap().stats;
    assert_eq!(memo_stats.edge_builds, 16);
    assert_eq!(memo_stats.edge_hits, 6);
}

#[test]
fn edge_state_memo_cells_match_individually_built_fleets() {
    // every memoized cell also equals a from-scratch Fleet::new(..).run()
    let spec = edge_memo_spec(2, true);
    let outcome = run_sweep(&spec).unwrap();
    for ((cell, report), (_, sc)) in outcome.reports.iter().zip(spec.cells()) {
        let direct = Fleet::new(FleetConfig {
            scenario: sc,
            seed: cell.seed,
        })
        .unwrap()
        .run();
        assert!(
            direct.bitwise_eq(report),
            "cell {} diverged from a fresh fleet",
            cell.index
        );
    }
}
