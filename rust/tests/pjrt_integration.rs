//! Integration: the PJRT-executed JAX/Pallas artifacts must numerically
//! agree with the native rust golden model — the cross-language contract
//! of the three-layer architecture.
//!
//! These tests skip (with a notice) when `artifacts/` has not been built;
//! `make test` always builds artifacts first.

use odl_har::linalg::Mat;
use odl_har::odl::{AlphaKind, OsElm, OsElmConfig};
use odl_har::runtime::{default_artifact_dir, PjrtOsElm, Runtime};
use odl_har::util::rng::Rng64;

fn runtime() -> Option<Runtime> {
    if !default_artifact_dir().join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(default_artifact_dir()).expect("runtime open"))
}

fn native_model(seed: u16) -> OsElm {
    let cfg = OsElmConfig {
        n_in: 561,
        n_hidden: 128,
        n_out: 6,
        alpha: AlphaKind::Hash,
        ..Default::default()
    };
    OsElm::new(cfg, &mut Rng64::new(1), seed)
}

fn random_data(rng: &mut Rng64, rows: usize) -> (Mat, Vec<usize>) {
    let mut xs = Mat::zeros(rows, 561);
    let mut labels = Vec::with_capacity(rows);
    for r in 0..rows {
        let c = rng.below(6);
        labels.push(c);
        for j in 0..561 {
            let mean = if j % 6 == c { 0.8 } else { -0.2 };
            *xs.at_mut(r, j) = rng.normal_ms(mean, 1.0) as f32;
        }
    }
    (xs, labels)
}

#[test]
fn predict_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng64::new(7);
    let (xs, _) = random_data(&mut rng, 4);

    let mut native = native_model(42);
    // random β so logits are nontrivial
    for (i, b) in native.beta.data.iter_mut().enumerate() {
        *b = ((i as f32) * 0.37).sin() * 0.3;
    }
    let mut pjrt = PjrtOsElm::new(&rt, 128, 42).unwrap();
    pjrt.load_state(&native.beta.data, &native.p.data).unwrap();

    for r in 0..xs.rows {
        let ln = native.logits(xs.row(r));
        let lp = pjrt.logits(xs.row(r)).unwrap();
        for (a, b) in ln.iter().zip(&lp) {
            assert!(
                (a - b).abs() < 1e-4,
                "logit mismatch: native {a} vs pjrt {b}"
            );
        }
        let pn = native.predict(xs.row(r));
        let pp = pjrt.predict(xs.row(r)).unwrap();
        assert_eq!(pn.class, pp.class);
        assert!((pn.confidence() - pp.confidence()).abs() < 1e-4);
    }
}

#[test]
fn train_step_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng64::new(9);
    let (xs, labels) = random_data(&mut rng, 8);

    let mut native = native_model(7);
    // P = 5·I prior (fresh-ish RLS state)
    for i in 0..128 {
        *native.p.at_mut(i, i) = 5.0;
    }
    let mut pjrt = PjrtOsElm::new(&rt, 128, 7).unwrap();
    pjrt.load_state(&native.beta.data, &native.p.data).unwrap();

    for r in 0..xs.rows {
        native.train_step(xs.row(r), labels[r]);
        pjrt.train_step(xs.row(r), labels[r]).unwrap();
    }
    let max_beta = native
        .beta
        .data
        .iter()
        .zip(&pjrt.beta)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    let max_p = native
        .p
        .data
        .iter()
        .zip(&pjrt.p)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_beta < 1e-3, "beta drift after 8 steps: {max_beta}");
    assert!(max_p < 1e-2, "P drift after 8 steps: {max_p}");
}

#[test]
fn init_batch_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng64::new(11);
    let (xs, labels) = random_data(&mut rng, 512);

    let mut native = native_model(3);
    native.init_batch(&xs, &labels).unwrap();
    let mut pjrt = PjrtOsElm::new(&rt, 128, 3).unwrap();
    pjrt.init_batch(&xs, &labels).unwrap();

    // β agreement (Newton–Schulz vs Cholesky: same SPD inverse to ~1e-3)
    let max_beta = native
        .beta
        .data
        .iter()
        .zip(&pjrt.beta)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_beta < 5e-3, "init beta mismatch: {max_beta}");

    // and the two models must agree on predictions
    let (test_xs, test_labels) = random_data(&mut rng, 64);
    let acc_native = native.accuracy(&test_xs, &test_labels);
    let acc_pjrt = pjrt.accuracy(&test_xs, &test_labels).unwrap();
    assert!(
        (acc_native - acc_pjrt).abs() < 0.04,
        "accuracy divergence: {acc_native} vs {acc_pjrt}"
    );
}

#[test]
fn batched_accuracy_handles_tail_padding() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng64::new(13);
    // 300 samples: one full 256 batch + a 44-sample padded tail
    let (xs, labels) = random_data(&mut rng, 300);
    let mut native = native_model(5);
    let (init, _) = (&xs, &labels);
    native.init_batch(init, labels.as_slice()).unwrap();
    let mut pjrt = PjrtOsElm::new(&rt, 128, 5).unwrap();
    pjrt.load_state(&native.beta.data, &native.p.data).unwrap();

    let acc_native = native.accuracy(&xs, &labels);
    let acc_pjrt = pjrt.accuracy(&xs, &labels).unwrap();
    assert!(
        (acc_native - acc_pjrt).abs() < 1e-9,
        "padded batch eval must match exactly: {acc_native} vs {acc_pjrt}"
    );
}

#[test]
fn n256_artifacts_work() {
    let Some(rt) = runtime() else { return };
    let mut pjrt = PjrtOsElm::new(&rt, 256, 1).unwrap();
    let mut rng = Rng64::new(17);
    let (xs, labels) = random_data(&mut rng, 512);
    pjrt.init_batch(&xs, &labels).unwrap();
    let acc = pjrt.accuracy(&xs, &labels).unwrap();
    assert!(acc > 0.8, "N=256 self-accuracy {acc}");
}
