//! Integration: the bit-accurate Q16.16 hardware golden model
//! ([`FixedOsElm`]) co-simulated against the f32 golden model on the HAR
//! protocol — quantifies the fixed-point accuracy loss the ASIC pays and
//! checks the cycle/power models stay consistent with the datapath the
//! fixed model actually executes.

use odl_har::data::{DriftSplit, Standardizer, SynthConfig, SynthHar};
use odl_har::fixed::{fx_vec_from_f32, Fx};
use odl_har::hw::{CycleModel, PowerModel, PowerState};
use odl_har::odl::fixed_oselm::FixedOsElm;
use odl_har::odl::{AlphaKind, OsElm, OsElmConfig};
use odl_har::util::rng::Rng64;

/// Reduced-size workload (the sequential-xorshift hidden loop in the
/// fixed model is O(n·N) per sample in software).
fn workload() -> (DriftSplit, usize, usize, usize) {
    let (n_in, n_hidden, n_out) = (64, 32, 4);
    let synth = SynthConfig {
        n_features: n_in,
        n_classes: n_out,
        n_subjects: 30,
        samples_per_cell: 12,
        proto_sigma: 1.1,
        confuse_frac: 0.04,
        ..Default::default()
    };
    let mut data_rng = Rng64::new(0xF1DE);
    let pool = SynthHar::new(synth, &mut data_rng).generate(&mut data_rng);
    let mut rng = Rng64::new(3);
    let mut split = DriftSplit::build(&pool, 0.7, &mut rng);
    let std = Standardizer::fit(&split.train.xs);
    std.apply(&mut split.train.xs);
    std.apply(&mut split.test0.xs);
    std.apply(&mut split.odl_stream.xs);
    std.apply(&mut split.test1.xs);
    (split, n_in, n_hidden, n_out)
}

#[test]
fn fixed_point_core_tracks_float_on_har_protocol() {
    let (split, n_in, n_hidden, n_out) = workload();

    // float golden model, trained on the full §3 protocol — provisioned
    // with the ASIC's *sequential*-stream α so its state is feature-
    // compatible with the fixed-point core (same seed ⇒ same weights).
    let cfg = OsElmConfig {
        n_in,
        n_hidden,
        n_out,
        alpha: AlphaKind::Hash,
        ..Default::default()
    };
    let mut float_model = OsElm::new(cfg, &mut Rng64::new(1), 7);
    float_model.set_alpha(odl_har::odl::alpha::AlphaProvider::hash_sequential(
        7,
        n_in,
        n_hidden,
        cfg.scale(),
    ));
    let k0 = (2 * n_hidden).max(100);
    let (init, rest) = split.train.split_at(k0);
    float_model.init_batch(&init.xs, &init.labels).unwrap();
    for r in 0..rest.len() {
        float_model.train_step(rest.xs.row(r), rest.labels[r]);
    }

    // hardware model provisioned from the float state (the deployment
    // story: offline init, on-device fixed-point ODL)
    let mut hw = FixedOsElm::new(n_in, n_hidden, n_out, 7);
    hw.load_from_float(&float_model.beta.data, &float_model.p.data)
        .unwrap();

    // both retrain on the drifted stream
    let fx_stream: Vec<Vec<Fx>> = (0..split.odl_stream.len())
        .map(|r| fx_vec_from_f32(split.odl_stream.xs.row(r)))
        .collect();
    for (r, fx) in fx_stream.iter().enumerate() {
        let label = split.odl_stream.labels[r];
        float_model.train_step(split.odl_stream.xs.row(r), label);
        hw.train_step(fx, label);
    }

    // post-drift accuracy: fixed-point loss must be small
    let acc_float = float_model.accuracy(&split.test1.xs, &split.test1.labels);
    let fx_test: Vec<Vec<Fx>> = (0..split.test1.len())
        .map(|r| fx_vec_from_f32(split.test1.xs.row(r)))
        .collect();
    let acc_fixed = hw.accuracy(&fx_test, &split.test1.labels);
    assert!(
        acc_float > 0.8,
        "float model failed to recover: {acc_float}"
    );
    assert!(
        (acc_float - acc_fixed).abs() < 0.08,
        "Q16.16 quantization loss too large: float {acc_float:.3} vs fixed {acc_fixed:.3}"
    );
}

#[test]
fn cycle_model_scales_with_the_datapath_it_charges() {
    // The cycle model's op counts must match what FixedOsElm executes:
    // hidden n·N MACs, Ph N², rank-1 N²+Nm elements. Scaling n, N, m must
    // move predicted cycles proportionally.
    let base = CycleModel::prototype().with_dims(64, 32, 4);
    let double_n = CycleModel::prototype().with_dims(128, 32, 4);
    let double_hidden = CycleModel::prototype().with_dims(64, 64, 4);

    // doubling n doubles the hidden MACs (dominant in predict)
    let p0 = base.predict_cycles() as f64;
    let p1 = double_n.predict_cycles() as f64;
    assert!((p1 / p0 - 2.0).abs() < 0.1, "predict n-scaling: {}", p1 / p0);

    // doubling N roughly quadruples the train-time N² terms
    let t0 = base.train_cycles() as f64;
    let t1 = double_hidden.train_cycles() as f64;
    assert!(t1 / t0 > 2.0, "train N-scaling too weak: {}", t1 / t0);
}

#[test]
fn energy_per_event_at_prototype_point() {
    // §3.3's per-event numbers: one predict + one train at 10 MHz draws
    // predict 3.39 mW × 36.4 ms + train 3.37 mW × 171.28 ms ≈ 0.70 mJ.
    let cyc = CycleModel::prototype();
    let pow = PowerModel::default();
    let e = pow.energy_mj(PowerState::Predict, cyc.predict_time_s())
        + pow.energy_mj(PowerState::Train, cyc.train_time_s());
    assert!(
        (e - 0.7006).abs() < 0.005,
        "per-event compute energy {e} mJ (expected ≈ 0.70)"
    );
}
