//! Chaos suite for `odl-har serve` + `odl-har loadgen`, driven through
//! the real binaries: seeded drop/delay/close/garble schedules on either
//! socket end (`--inject-faults`, see `util::faults`), client-process
//! kills mid-stream, and drain/restart splits — all asserting the
//! server's drained snapshot is **byte-identical** to an undisturbed
//! run's. The wire protocol dedups by sequence number and both ends
//! retry, so every recoverable transport fault must converge on the
//! exact same per-client OS-ELM/pruner/teacher state.
//!
//! The `[serve]` section here pins `workers = 2`, so every scenario runs
//! against the shard worker-pool engine, and the batched tests exercise
//! `--batch` framing (`events`/`decisions`) under the same fault kinds.

use std::io::{BufRead, BufReader, Read as _};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};

/// Tiny scenario (72-row provisioning pool over 12 features) so a full
/// chaos matrix stays in CI time. `warmup = 4` makes the pruner actually
/// skip events inside short streams.
const CONFIG: &str = r#"
[fleet]
n_hidden = 16
seed = 11
data_seed = 77

[teacher]
error_rate = 0.1

[data]
n_features = 12
n_classes = 3
n_subjects = 2
samples_per_cell = 12

[serve]
max_clients = 8
queue_depth = 16
read_timeout_ms = 20
idle_timeout_ms = 5000
retry_after_ms = 5
workers = 2
max_batch = 8
warmup = 4
"#;

fn exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_odl-har"))
}

struct Setup {
    dir: PathBuf,
    cfg: PathBuf,
}

fn setup(name: &str) -> Setup {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("serve.toml");
    std::fs::write(&cfg, CONFIG).unwrap();
    Setup { dir, cfg }
}

/// A running `odl-har serve` child and the ephemeral address it bound.
struct Server {
    child: Child,
    addr: String,
}

fn start_server(cfg: &Path, snapshot: &Path, faults: Option<&str>) -> Server {
    let mut cmd = Command::new(exe());
    cmd.arg("serve")
        .arg("--config")
        .arg(cfg)
        .arg("--bind")
        .arg("127.0.0.1:0")
        .arg("--snapshot")
        .arg(snapshot)
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit());
    if let Some(spec) = faults {
        cmd.arg("--inject-faults").arg(spec);
    }
    let mut child = cmd.spawn().expect("spawning serve");
    // the flushed ready line is the port-handoff contract
    let mut reader = BufReader::new(child.stdout.take().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).expect("reading the ready line");
    let addr = line
        .trim()
        .strip_prefix("serve: listening on ")
        .unwrap_or_else(|| panic!("unexpected ready line: {line:?}"))
        .to_string();
    // keep draining stdout so the child never blocks on a full pipe
    std::thread::spawn(move || {
        let mut rest = String::new();
        let _ = reader.read_to_string(&mut rest);
    });
    Server { child, addr }
}

fn loadgen_cmd(addr: &str, cfg: &Path, client: &str, events: usize, batch: usize) -> Command {
    let mut cmd = Command::new(exe());
    cmd.arg("loadgen")
        .arg("--connect")
        .arg(addr)
        .arg("--config")
        .arg(cfg)
        .arg("--client")
        .arg(client)
        .arg("--events")
        .arg(events.to_string())
        .arg("--retry-budget")
        .arg("5")
        .arg("--backoff-base-ms")
        .arg("2")
        .arg("--backoff-cap-ms")
        .arg("20")
        .arg("--reply-timeout-ms")
        .arg("150")
        .stdout(Stdio::piped())
        .stderr(Stdio::piped());
    if batch > 1 {
        cmd.arg("--batch").arg(batch.to_string());
    }
    cmd
}

/// Run `n` loadgen clients concurrently (edge-0 .. edge-{n-1}), assert
/// each delivered every event, and return their summary JSON lines.
fn run_clients(
    addr: &str,
    cfg: &Path,
    n: usize,
    events: usize,
    faults: Option<&str>,
    batch: usize,
) -> Vec<String> {
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let client = format!("edge-{i}");
                let addr = addr.to_string();
                scope.spawn(move || {
                    let mut cmd = loadgen_cmd(&addr, cfg, &client, events, batch);
                    if let Some(spec) = faults {
                        cmd.arg("--inject-faults").arg(spec);
                    }
                    let out = cmd.output().expect("spawning loadgen");
                    assert!(
                        out.status.success(),
                        "loadgen {client} failed: {}",
                        String::from_utf8_lossy(&out.stderr)
                    );
                    let text = String::from_utf8_lossy(&out.stdout).into_owned();
                    assert!(
                        text.contains(&format!("\"delivered\":{events}")),
                        "loadgen {client} must deliver all {events} events: {text}"
                    );
                    text
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Drain the server (a zero-event loadgen run with `--shutdown`), wait
/// for it to exit cleanly, and return the published snapshot bytes.
fn drain_and_snapshot(mut server: Server, cfg: &Path, snapshot: &Path) -> Vec<u8> {
    let out = loadgen_cmd(&server.addr, cfg, "edge-0", 0, 1)
        .arg("--shutdown")
        .output()
        .expect("spawning the drain client");
    assert!(
        out.status.success(),
        "drain client failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let status = server.child.wait().expect("waiting for serve");
    assert!(status.success(), "serve must drain to a clean exit");
    std::fs::read(snapshot).expect("the drained snapshot must exist")
}

/// One full scenario: fresh server, `n` concurrent clients, drain.
fn run_scenario(
    s: &Setup,
    tag: &str,
    n: usize,
    events: usize,
    server_faults: Option<&str>,
    client_faults: Option<&str>,
    batch: usize,
) -> Vec<u8> {
    let snap = s.dir.join(format!("snap_{tag}.json"));
    let server = start_server(&s.cfg, &snap, server_faults);
    run_clients(&server.addr, &s.cfg, n, events, client_faults, batch);
    drain_and_snapshot(server, &s.cfg, &snap)
}

/// drop/delay/close/garble on both socket ends, at 1, 2, and 8 clients:
/// the drained per-client state must match an undisturbed run's, byte
/// for byte. Explicit sites pin each fault kind; the server indices
/// count globally across every connection, the client indices per
/// loadgen process.
#[test]
fn explicit_fault_schedules_converge_to_the_undisturbed_snapshot() {
    let s = setup("odl_har_serve_chaos_explicit");
    let spec = "5:drop@3#1,garble@7#1,delay@11#1,close@13#1,drop@4#2,garble@9#2,delay@6#2,close@14#2";
    for n in [1usize, 2, 8] {
        let clean = run_scenario(&s, &format!("clean_{n}"), n, 24, None, None, 1);
        assert!(
            clean.windows(8).any(|w| w == b"\"edge-0\""),
            "the snapshot must carry per-client state"
        );
        let chaos = run_scenario(&s, &format!("chaos_{n}"), n, 24, Some(spec), Some(spec), 1);
        assert_eq!(
            chaos, clean,
            "{n} client(s): the disturbed run must converge on the clean state"
        );
    }
    let _ = std::fs::remove_dir_all(&s.dir);
}

/// A bare seed draws recoverable net faults (~1/6 of messages, both ends,
/// different streams) — full-random chaos must still converge.
#[test]
fn seeded_chaos_converges_to_the_undisturbed_snapshot() {
    let s = setup("odl_har_serve_chaos_seeded");
    let clean = run_scenario(&s, "clean", 2, 24, None, None, 1);
    let chaos = run_scenario(&s, "chaos", 2, 24, Some("1701"), Some("1701"), 1);
    assert_eq!(chaos, clean, "seeded chaos must converge on the clean state");
    let _ = std::fs::remove_dir_all(&s.dir);
}

/// A client process killed mid-stream (injected abort at its 5th send)
/// loses nothing durable: a rerun replays the same deterministic event
/// stream, the server's watermark dedups the prefix, and the drained
/// state matches a run that never crashed.
#[test]
fn killed_client_rerun_replays_to_the_clean_state() {
    let s = setup("odl_har_serve_chaos_kill");
    let clean = run_scenario(&s, "clean", 2, 24, None, None, 1);

    let snap = s.dir.join("snap_kill.json");
    let server = start_server(&s.cfg, &snap, None);
    // edge-1 runs undisturbed; edge-0 aborts mid-stream
    let out = loadgen_cmd(&server.addr, &s.cfg, "edge-1", 24, 1)
        .output()
        .expect("spawning loadgen edge-1");
    assert!(out.status.success());
    let killed = loadgen_cmd(&server.addr, &s.cfg, "edge-0", 24, 1)
        .arg("--inject-faults")
        .arg("5:kill@5#2")
        .output()
        .expect("spawning the doomed loadgen");
    assert!(
        !killed.status.success(),
        "the kill site must abort the client process"
    );
    // rerun without faults: welcome fast-forwards past the applied prefix
    let rerun = loadgen_cmd(&server.addr, &s.cfg, "edge-0", 24, 1)
        .output()
        .expect("spawning the rerun loadgen");
    assert!(
        rerun.status.success(),
        "rerun failed: {}",
        String::from_utf8_lossy(&rerun.stderr)
    );
    let text = String::from_utf8_lossy(&rerun.stdout);
    assert!(
        text.contains("\"delivered\":24"),
        "the rerun must finish the stream: {text}"
    );
    let bytes = drain_and_snapshot(server, &s.cfg, &snap);
    assert_eq!(bytes, clean, "crash + rerun must converge on the clean state");
    let _ = std::fs::remove_dir_all(&s.dir);
}

/// Batched frames at 2 and 8 clients, with garble/close schedules on
/// both socket ends: every snapshot must be byte-identical to the clean
/// *unbatched* run's — batching changes the wire shape only, and chaos
/// on batched frames still converges. Client fault indices are small
/// because a batched stream sends ~K× fewer messages (hello = 0, then
/// one frame per 6 events).
#[test]
fn batched_frames_chaos_converges_to_the_unbatched_clean_snapshot() {
    let s = setup("odl_har_serve_chaos_batched");
    let spec = "5:garble@2#1,close@4#1,garble@2#2,close@4#2";
    for n in [2usize, 8] {
        let clean = run_scenario(&s, &format!("clean_{n}"), n, 24, None, None, 1);
        let batched = run_scenario(&s, &format!("batched_{n}"), n, 24, None, None, 6);
        assert_eq!(
            batched, clean,
            "{n} client(s): batch 6 must apply the same state as unbatched"
        );
        let chaos = run_scenario(&s, &format!("bchaos_{n}"), n, 24, Some(spec), Some(spec), 6);
        assert_eq!(
            chaos, clean,
            "{n} client(s): chaos on batched frames must converge on the clean state"
        );
    }
    let _ = std::fs::remove_dir_all(&s.dir);
}

/// A batched client killed mid-stream (abort at its 4th send — hello
/// plus three 6-event frames) replays on rerun: the watermark welcome
/// fast-forwards past the applied prefix, resent frames ack as
/// duplicates, and the drained state matches the clean unbatched run.
#[test]
fn killed_batched_client_rerun_replays_to_the_clean_state() {
    let s = setup("odl_har_serve_chaos_batched_kill");
    let clean = run_scenario(&s, "clean", 2, 24, None, None, 1);

    let snap = s.dir.join("snap_bkill.json");
    let server = start_server(&s.cfg, &snap, None);
    let out = loadgen_cmd(&server.addr, &s.cfg, "edge-1", 24, 6)
        .output()
        .expect("spawning loadgen edge-1");
    assert!(out.status.success());
    let killed = loadgen_cmd(&server.addr, &s.cfg, "edge-0", 24, 6)
        .arg("--inject-faults")
        .arg("5:kill@3#2")
        .output()
        .expect("spawning the doomed batched loadgen");
    assert!(
        !killed.status.success(),
        "the kill site must abort the client process"
    );
    let rerun = loadgen_cmd(&server.addr, &s.cfg, "edge-0", 24, 6)
        .output()
        .expect("spawning the rerun loadgen");
    assert!(
        rerun.status.success(),
        "rerun failed: {}",
        String::from_utf8_lossy(&rerun.stderr)
    );
    let text = String::from_utf8_lossy(&rerun.stdout);
    assert!(
        text.contains("\"delivered\":24"),
        "the rerun must finish the stream: {text}"
    );
    let bytes = drain_and_snapshot(server, &s.cfg, &snap);
    assert_eq!(bytes, clean, "crash + batched rerun must converge on the clean state");
    let _ = std::fs::remove_dir_all(&s.dir);
}

/// Graceful drain is a real checkpoint: 20 events, drain, restart from
/// the snapshot, finish to 40 — byte-identical to one uninterrupted
/// 40-event run. The event stream is prefix-stable and the welcome
/// watermark fast-forwards the client, so nothing replays twice.
#[test]
fn drain_and_restart_resumes_byte_identically() {
    let s = setup("odl_har_serve_chaos_restart");
    let full = run_scenario(&s, "full", 2, 40, None, None, 1);

    let snap = s.dir.join("snap_split.json");
    let server = start_server(&s.cfg, &snap, None);
    run_clients(&server.addr, &s.cfg, 2, 20, None, 1);
    let first = drain_and_snapshot(server, &s.cfg, &snap);
    assert_ne!(first, full, "the 20-event checkpoint is not the final state");

    let server = start_server(&s.cfg, &snap, None);
    // the restarted server restores both clients; each rerun asks for the
    // full 40 and is fast-forwarded past its applied 20 by the welcome
    let summaries = run_clients(&server.addr, &s.cfg, 2, 40, None, 1);
    for text in &summaries {
        assert!(
            text.contains("\"acked\":20"),
            "only the unapplied suffix may be re-sent: {text}"
        );
    }
    let second = drain_and_snapshot(server, &s.cfg, &snap);
    assert_eq!(
        second, full,
        "drain + restart must be byte-identical to the uninterrupted run"
    );
    let _ = std::fs::remove_dir_all(&s.dir);
}
