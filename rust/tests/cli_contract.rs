//! CLI misuse contract, pinned against the real binary: an unknown
//! subcommand or a missing required argument exits **non-zero** with the
//! usage block on **stderr**, while stdout stays clean (a script piping
//! `odl-har` output must never parse half a banner). `help` is the one
//! place usage goes to stdout — and it must list every subcommand,
//! including `serve`/`loadgen`.

use std::process::{Command, Output};

fn run(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_odl-har"))
        .args(args)
        .output()
        .expect("spawning the odl-har CLI")
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

/// The usage banner's first line — present exactly where usage belongs.
const BANNER: &str = "odl-har — tiny supervised ODL core";

#[test]
fn unknown_subcommand_fails_with_usage_on_stderr() {
    let out = run(&["frobnicate"]);
    assert!(!out.status.success(), "unknown subcommand must exit non-zero");
    let err = stderr(&out);
    assert!(err.contains(BANNER), "usage must go to stderr, got: {err}");
    assert!(
        err.contains("unknown subcommand 'frobnicate'"),
        "the offending word must be named: {err}"
    );
    assert!(
        stdout(&out).is_empty(),
        "stdout must stay clean on misuse, got: {}",
        stdout(&out)
    );
}

#[test]
fn missing_required_args_fail_with_usage_on_stderr() {
    // every subcommand with a required option, driven without it
    let cases: &[(&[&str], &str)] = &[
        (&["run"], "run requires --config"),
        (&["sweep"], "sweep requires --config"),
        (&["merge"], "merge requires --config"),
        (&["serve"], "serve requires --config"),
        (&["loadgen"], "loadgen requires --connect"),
        (&["loadgen", "--connect", "127.0.0.1:1"], "loadgen requires --config"),
    ];
    for (args, want) in cases {
        let out = run(args);
        assert!(!out.status.success(), "{args:?} must exit non-zero");
        let err = stderr(&out);
        assert!(err.contains(BANNER), "{args:?}: usage must go to stderr: {err}");
        assert!(err.contains(want), "{args:?}: expected '{want}' in: {err}");
        assert!(
            stdout(&out).is_empty(),
            "{args:?}: stdout must stay clean on misuse"
        );
    }
}

#[test]
fn option_missing_its_value_fails() {
    let out = run(&["table2", "--trials"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("--trials requires a value"));
}

#[test]
fn unrecognized_flag_fails() {
    let out = run(&["table1", "--frobnicate"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("unrecognized arguments"));
}

#[test]
fn help_lists_every_subcommand_on_stdout() {
    for invocation in [&["help"][..], &["--help"][..], &["-h"][..]] {
        let out = run(invocation);
        assert!(out.status.success(), "{invocation:?} is not an error");
        let text = stdout(&out);
        assert!(text.contains(BANNER));
        for sub in [
            "table1", "table2", "table3", "table4", "fig1", "fig3", "fig4", "run",
            "fleet", "sweep", "merge", "serve", "loadgen", "artifacts-check",
        ] {
            assert!(
                text.contains(sub),
                "{invocation:?}: help must list '{sub}'"
            );
        }
        assert!(stderr(&out).is_empty(), "help writes nothing to stderr");
    }
}

#[test]
fn loadgen_against_a_dead_address_degrades_with_a_diagnostic() {
    // port 1 on localhost refuses immediately; a zero retry budget makes
    // this fast. The client must exit non-zero and explain the degraded
    // offline mode rather than hang or panic.
    let dir = std::env::temp_dir().join("odl_har_cli_contract_loadgen");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("serve.toml");
    std::fs::write(
        &cfg,
        "[fleet]\nn_hidden = 16\nseed = 7\n\n[data]\nn_features = 12\nn_classes = 3\nn_subjects = 2\nsamples_per_cell = 12\n",
    )
    .unwrap();
    let out = run(&[
        "loadgen",
        "--connect",
        "127.0.0.1:1",
        "--config",
        cfg.to_str().unwrap(),
        "--events",
        "4",
        "--retry-budget",
        "0",
        "--backoff-base-ms",
        "1",
    ]);
    assert!(!out.status.success(), "an unreachable server is an error");
    let err = stderr(&out);
    assert!(
        err.contains("unreachable") && err.contains("buffered"),
        "the degraded-mode diagnostic must name the buffered events: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
