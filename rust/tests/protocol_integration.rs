//! Integration: the §3 protocol at full 561-dim scale — reduced-trial
//! Table-3 / Figure-3 shape assertions (the full 20-trial runs live in
//! `cargo bench`).

use odl_har::exp::protocol::{run, ProtocolConfig, PruningSpec, Variant};
use odl_har::odl::AlphaKind;

fn cfg(variant: Variant, n_hidden: usize) -> ProtocolConfig {
    let mut c = ProtocolConfig::new(variant, n_hidden);
    c.trials = 3;
    c
}

#[test]
fn table3_shape_n128() {
    let no_odl = run(&cfg(Variant::NoOdl(AlphaKind::Hash), 128)).unwrap();
    let hash = run(&cfg(Variant::Odl(AlphaKind::Hash), 128)).unwrap();
    let base = run(&cfg(Variant::Odl(AlphaKind::Stored), 128)).unwrap();

    // paper: before ≈ 93, NoODL after ≈ 83 (−10), ODL after ≈ 90.7
    assert!(
        (88.0..96.0).contains(&no_odl.before.mean()),
        "before {}",
        no_odl.before.mean()
    );
    assert!(
        no_odl.after.mean() < no_odl.before.mean() - 6.0,
        "drift drop too small: {} -> {}",
        no_odl.before.mean(),
        no_odl.after.mean()
    );
    for (name, agg) in [("hash", &hash), ("base", &base)] {
        assert!(
            agg.after.mean() > no_odl.after.mean() + 4.0,
            "{name} recovery missing: {} vs noodl {}",
            agg.after.mean(),
            no_odl.after.mean()
        );
    }
    // ODLHash ≈ ODLBase (the paper's hash-replacement claim)
    assert!(
        (hash.after.mean() - base.after.mean()).abs() < 3.0,
        "hash {} vs base {}",
        hash.after.mean(),
        base.after.mean()
    );
}

#[test]
fn capacity_ordering_n256_beats_n128_before_drift() {
    let a = run(&cfg(Variant::Odl(AlphaKind::Hash), 128)).unwrap();
    let b = run(&cfg(Variant::Odl(AlphaKind::Hash), 256)).unwrap();
    assert!(
        b.before.mean() > a.before.mean() + 1.0,
        "N=256 {} must beat N=128 {}",
        b.before.mean(),
        a.before.mean()
    );
}

#[test]
fn pruning_tradeoff_at_full_scale() {
    let mut full = cfg(Variant::Odl(AlphaKind::Hash), 128);
    full.pruning = PruningSpec::Off;
    let mut auto = cfg(Variant::Odl(AlphaKind::Hash), 128);
    auto.pruning = PruningSpec::Auto { x: 10 };
    let full = run(&full).unwrap();
    let auto = run(&auto).unwrap();
    // paper §3.2: 55.7 % comm reduction at ≤ 0.9 pt accuracy cost
    let reduction = 100.0 - auto.comm.mean();
    assert!(reduction > 40.0, "auto reduction only {reduction:.1} %");
    assert!(
        full.after.mean() - auto.after.mean() < 2.5,
        "accuracy cost too high: {} vs {}",
        full.after.mean(),
        auto.after.mean()
    );
}

#[test]
fn dnn_baseline_also_degrades_under_drift() {
    let dnn = run(&cfg(Variant::Dnn(vec![561, 512, 256, 6]), 0)).unwrap();
    assert!(
        (85.0..97.0).contains(&dnn.before.mean()),
        "dnn before {}",
        dnn.before.mean()
    );
    assert!(
        dnn.after.mean() < dnn.before.mean() - 4.0,
        "a frozen DNN must also drop: {} -> {}",
        dnn.before.mean(),
        dnn.after.mean()
    );
}
