//! Bench + regeneration for Table 2 (parameter count + accuracy vs SOTA).

use odl_har::exp::table2;
use odl_har::util::bench::{bench, bench_trials};

fn main() {
    let trials = bench_trials();
    let t0 = std::time::Instant::now();
    let table = table2::run_table(trials).expect("table2");
    println!("{}", table.render());
    println!(
        "table2 regeneration ({} trials x 2 configs): {:.1} s",
        trials,
        t0.elapsed().as_secs_f64()
    );
    // micro: the parameter-count model itself
    bench("odl_param_count", 10, 100, || {
        for n in [32, 64, 128, 256, 512] {
            std::hint::black_box(odl_har::hw::memory::odl_param_count(n, 6));
        }
    });
}
