//! Bench + regeneration for Figure 3 (pruning sweep + auto-θ headline),
//! including the Error-L2-Norm ablation the paper mentions but omits.

use odl_har::exp::fig3;
use odl_har::pruning::Metric;
use odl_har::util::bench::bench_trials;

fn main() {
    let trials = bench_trials();
    let t0 = std::time::Instant::now();
    let points = fig3::sweep(trials, Metric::P1P2).expect("fig3 sweep");
    let (table, _) = fig3::render(&points, trials, Metric::P1P2).expect("render");
    println!("{}", table.render());
    if let Some((red, drop)) = fig3::auto_headline(&points) {
        println!(
            "Auto: comm reduction {red:.1} % (paper 55.7 %), accuracy drop {drop:.1} pt (paper 0.9 pt)"
        );
        assert!(red > 30.0, "auto must cut communication substantially");
        assert!(drop < 2.5, "auto accuracy loss must stay small");
    }
    println!("fig3 (P1P2) regeneration: {:.1} s", t0.elapsed().as_secs_f64());

    // Ablation: the Error-L2-Norm confidence metric (paper §3.2 footnote)
    let points_el2n = fig3::sweep(trials, Metric::ErrorL2).expect("el2n sweep");
    let (table, _) = fig3::render(&points_el2n, trials, Metric::ErrorL2).expect("render");
    println!("{}", table.render());
    if let Some((red, drop)) = fig3::auto_headline(&points_el2n) {
        println!("Auto (EL2N): comm reduction {red:.1} %, accuracy drop {drop:.1} pt");
    }
}
