//! Bench + regeneration for Table 1 (SRAM size model).
//!
//! Prints the exact table and times the memory-model sweep (sub-µs — the
//! model is closed-form; the bench guards against accidental regressions
//! into something expensive).

use odl_har::exp::table1;
use odl_har::hw::memory::{memory_bytes, CoreVariant};
use odl_har::util::bench::bench;

fn main() {
    println!("{}", table1::run().render());
    bench("table1_memory_model_sweep", 10, 100, || {
        let mut acc = 0usize;
        for &n in &table1::N_SWEEP {
            for v in [CoreVariant::NoOdl, CoreVariant::OdlBase, CoreVariant::OdlHash] {
                acc = acc.wrapping_add(memory_bytes(v, 561, n, 6));
            }
        }
        std::hint::black_box(acc);
    });
}
