//! Bench + regeneration for Figure 1 (per-class PCA projections).

use odl_har::data::{SynthConfig, SynthHar};
use odl_har::exp::fig1;
use odl_har::util::bench::bench;
use odl_har::util::rng::Rng64;

fn main() {
    let mut data_rng = Rng64::new(0xDA7A_5EED);
    let pool = SynthHar::new(SynthConfig::default(), &mut data_rng).generate(&mut data_rng);
    let out = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&out).unwrap();
    let t0 = std::time::Instant::now();
    let table = fig1::run(&pool, &out, 7).expect("fig1");
    println!("{}", table.render());
    println!("fig1 regeneration: {:.1} s", t0.elapsed().as_secs_f64());

    // micro: PCA fit on one class
    let class0 = pool.filter(|l, _| l == 0);
    let mut rng = Rng64::new(3);
    bench("pca_fit_2_components (one class)", 1, 5, || {
        std::hint::black_box(odl_har::data::pca::Pca::fit(&class0.xs, 2, &mut rng));
    });
}
