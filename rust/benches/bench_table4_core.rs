//! Bench + regeneration for Table 4 (core latency/power) + the divider
//! ablation + the fixed-point golden model's software throughput.

use odl_har::exp::table4;
use odl_har::fixed::fx_vec_from_f32;
use odl_har::odl::fixed_oselm::FixedOsElm;
use odl_har::util::bench::bench;
use odl_har::util::rng::Rng64;

fn main() {
    println!("{}", table4::run(true).render());
    println!("{}", table4::divider_ablation().render());

    // How fast does the bit-accurate Q16.16 golden model run in software?
    // (The ASIC does 171.28 ms/train at 10 MHz; the software model's rate
    // bounds how fast we can co-simulate.)
    let mut rng = Rng64::new(1);
    let mut m = FixedOsElm::new(561, 128, 6, 7);
    for i in 0..128 {
        m.p[i * 128 + i] = odl_har::fixed::Fx::from_f32(5.0);
    }
    let x: Vec<f32> = (0..561).map(|_| rng.normal() as f32).collect();
    let fx = fx_vec_from_f32(&x);
    bench("fixed_oselm_train_step (561/128/6)", 2, 20, || {
        m.train_step(&fx, 3);
    });
    bench("fixed_oselm_predict (561/128/6)", 2, 20, || {
        std::hint::black_box(m.predict(&fx));
    });
}
