//! Bench + regeneration for Table 3 (the central accuracy experiment).
//!
//! `ODL_BENCH_TRIALS` (default 20, paper's count) controls the trial
//! budget; `ODL_BENCH_FAST=1` drops to 3 for smoke runs.

use odl_har::exp::table3;
use odl_har::util::bench::bench_trials;

fn main() {
    let trials = bench_trials();
    let t0 = std::time::Instant::now();
    let (table, aggs) = table3::run_table(trials).expect("table3");
    println!("{}", table.render());
    println!(
        "table3 regeneration ({} trials x {} configs): {:.1} s total",
        trials,
        aggs.len(),
        t0.elapsed().as_secs_f64()
    );
    // shape assertions so `cargo bench` fails loudly on regression
    let no_odl_128 = &aggs[0];
    let hash_128 = &aggs[2];
    assert!(
        no_odl_128.after.mean() < no_odl_128.before.mean() - 5.0,
        "drift must hurt NoODL"
    );
    assert!(
        hash_128.after.mean() > no_odl_128.after.mean() + 4.0,
        "ODL must recover"
    );
    println!("table3 shape checks OK");
}
