//! Hot-path microbenchmarks across all three layers: the native OS-ELM
//! core (L3 state), the PJRT-executed artifacts (L2/L1), and the fleet
//! event loop. §Perf of EXPERIMENTS.md tracks these numbers.

use odl_har::coordinator::fleet::{Fleet, FleetConfig, Scenario};
use odl_har::data::SynthConfig;
use odl_har::linalg::Mat;
use odl_har::odl::{AlphaKind, OsElm, OsElmConfig};
use odl_har::util::bench::{bench, fast_mode};
use odl_har::util::rng::Rng64;

fn main() {
    let mut rng = Rng64::new(1);
    let cfg = OsElmConfig {
        n_in: 561,
        n_hidden: 128,
        n_out: 6,
        alpha: AlphaKind::Hash,
        ..Default::default()
    };
    let mut model = OsElm::new(cfg, &mut rng, 7);
    let mut xs = Mat::zeros(512, 561);
    let mut labels = Vec::new();
    for r in 0..512 {
        let c = r % 6;
        labels.push(c);
        for j in 0..561 {
            *xs.at_mut(r, j) = rng.normal_ms(if j % 6 == c { 0.5 } else { 0.0 }, 1.0) as f32;
        }
    }
    model.init_batch(&xs, &labels).unwrap();

    // L3 native hot path
    let x = xs.row(0).to_vec();
    bench("native predict (561/128/6)", 10, 200, || {
        std::hint::black_box(model.predict(&x));
    });
    bench("native train_step (561/128/6)", 10, 200, || {
        model.train_step(&x, 3);
    });
    let mut model256 = OsElm::new(
        OsElmConfig {
            n_hidden: 256,
            ..cfg
        },
        &mut rng,
        7,
    );
    model256.init_batch(&xs, &labels).unwrap();
    bench("native train_step (561/256/6)", 5, 100, || {
        model256.train_step(&x, 3);
    });
    let r = bench("native init_batch (512 samples, N=128)", 1, 5, || {
        model.init_batch(&xs, &labels).unwrap();
    });
    println!("  -> {:.0} samples/s batch init", r.per_sec(512.0));

    // L2/L1 via PJRT (skipped when artifacts are absent)
    if odl_har::runtime::default_artifact_dir().join("manifest.json").exists() {
        let rt = odl_har::runtime::Runtime::open_default().expect("runtime");
        let mut pjrt = odl_har::runtime::PjrtOsElm::new(&rt, 128, 7).expect("pjrt model");
        pjrt.load_state(&model.beta.data, &model.p.data).unwrap();
        bench("pjrt predict_one (561/128/6)", 5, 100, || {
            std::hint::black_box(pjrt.predict(&x).unwrap());
        });
        bench("pjrt train_step (561/128/6)", 5, 100, || {
            pjrt.train_step(&x, 3).unwrap();
        });
        let r = bench("pjrt train_stream 512 (scan-fused, K=32)", 1, 10, || {
            pjrt.train_stream(&xs, &labels).unwrap();
        });
        println!(
            "  -> {:.3} ms/sample scan-fused ({:.0} samples/s)",
            r.mean_s * 1e3 / 512.0,
            r.per_sec(512.0)
        );
        let r = bench("pjrt predict_batch 256 (561/128/6)", 3, 30, || {
            std::hint::black_box(pjrt.accuracy(&xs, &labels).unwrap());
        });
        println!("  -> {:.0} samples/s batched eval", r.per_sec(512.0));
    } else {
        println!("(skipping PJRT benches: run `make artifacts` first)");
    }

    // fleet event loop (coordination overhead per event)
    let scenario = Scenario {
        n_edges: 4,
        horizon_s: if fast_mode() { 60.0 } else { 300.0 },
        synth: SynthConfig {
            n_features: 561,
            ..Default::default()
        },
        ..Default::default()
    };
    let events = (scenario.horizon_s / scenario.event_period_s) as f64 * 4.0;
    let build = bench("fleet construction (provision 4 edges)", 0, 3, || {
        std::hint::black_box(
            Fleet::new(FleetConfig {
                scenario: scenario.clone(),
                seed: 1,
            })
            .unwrap(),
        );
    });
    let r = bench("fleet construct + event loop (4 edges)", 0, 3, || {
        let fleet = Fleet::new(FleetConfig {
            scenario: scenario.clone(),
            seed: 1,
        })
        .unwrap();
        std::hint::black_box(fleet.run());
    });
    let loop_s = (r.mean_s - build.mean_s).max(1e-9);
    println!(
        "  -> {:.0} fleet events/s simulated (loop only, {:.1} us/event)",
        events / loop_s,
        loop_s / events * 1e6
    );
}
