//! Hot-path microbenchmarks across all three layers: the native OS-ELM
//! core (L3 state), the PJRT-executed artifacts (L2/L1), and the fleet
//! event loop. §Perf of EXPERIMENTS.md and rust/PERF.md track these
//! numbers.
//!
//! Besides the kernel-layer hot path, this bench re-implements the
//! **pre-kernel scalar baseline** (the seed's row-axpy hidden layer,
//! 4-way dot, and full-N² Sherman–Morrison sweep) and times both on the
//! same machine in the same process, so every run produces its own
//! before/after comparison. Results are also written machine-readably to
//! `BENCH_hotpath.json` (override the path with `ODL_BENCH_JSON`), which
//! is how the perf trajectory is tracked from PR to PR.

use odl_har::coordinator::fleet::{Fleet, FleetConfig, Scenario};
use odl_har::data::SynthConfig;
use odl_har::linalg::Mat;
use odl_har::odl::activation::sigmoid_inplace;
use odl_har::odl::{AlphaKind, OsElm, OsElmConfig};
use odl_har::util::bench::{bench, fast_mode, BenchResult};
use odl_har::util::json::{obj, Json};
use odl_har::util::rng::Rng64;
use odl_har::util::stats::argmax;

/// The seed's 4-way unrolled dot (pre-kernel reference).
fn naive_dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let k = i * 4;
        acc[0] += a[k] * b[k];
        acc[1] += a[k + 1] * b[k + 1];
        acc[2] += a[k + 2] * b[k + 2];
        acc[3] += a[k + 3] * b[k + 3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Pre-kernel-layer scalar OS-ELM: the seed's exact predict/train_step
/// schedule (row-axpy hidden walk with an N-wide in-memory accumulator,
/// full-matrix rank-1 P sweep), run against copies of the same state.
struct BaselineModel {
    n: usize,
    nh: usize,
    m: usize,
    alpha: Vec<f32>,
    beta: Vec<f32>,
    p: Vec<f32>,
    h: Vec<f32>,
    ph: Vec<f32>,
    err: Vec<f32>,
    logits: Vec<f32>,
}

impl BaselineModel {
    fn from(model: &OsElm) -> Self {
        Self {
            n: model.cfg.n_in,
            nh: model.cfg.n_hidden,
            m: model.cfg.n_out,
            alpha: model.alpha.data().to_vec(),
            beta: model.beta.data.clone(),
            p: model.p.data.clone(),
            h: vec![0.0; model.cfg.n_hidden],
            ph: vec![0.0; model.cfg.n_hidden],
            err: vec![0.0; model.cfg.n_out],
            logits: vec![0.0; model.cfg.n_out],
        }
    }

    fn hidden(&mut self, x: &[f32]) {
        self.h.fill(0.0);
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &self.alpha[i * self.nh..(i + 1) * self.nh];
            for (o, &w) in self.h.iter_mut().zip(row) {
                *o += xi * w;
            }
        }
        sigmoid_inplace(&mut self.h);
    }

    fn predict(&mut self, x: &[f32]) -> usize {
        self.hidden(x);
        self.logits.fill(0.0);
        for i in 0..self.nh {
            let hi = self.h[i];
            if hi == 0.0 {
                continue;
            }
            let brow = &self.beta[i * self.m..(i + 1) * self.m];
            for (l, &b) in self.logits.iter_mut().zip(brow) {
                *l += hi * b;
            }
        }
        argmax(&self.logits)
    }

    fn train_step(&mut self, x: &[f32], label: usize) {
        let (nh, m) = (self.nh, self.m);
        self.hidden(x);
        for i in 0..nh {
            self.ph[i] = naive_dot(&self.p[i * nh..(i + 1) * nh], &self.h);
        }
        let denom = 1.0 + naive_dot(&self.h, &self.ph);
        let inv_denom = 1.0 / denom;
        for j in 0..m {
            self.err[j] = if j == label { 1.0 } else { 0.0 };
        }
        for i in 0..nh {
            let hi = self.h[i];
            if hi == 0.0 {
                continue;
            }
            let brow = &self.beta[i * m..(i + 1) * m];
            for (e, &b) in self.err.iter_mut().zip(brow) {
                *e -= hi * b;
            }
        }
        // the seed's fused full-N² rank-1 sweeps
        for i in 0..nh {
            let s = self.ph[i] * inv_denom;
            if s == 0.0 {
                continue;
            }
            let prow = &mut self.p[i * nh..(i + 1) * nh];
            for (pj, &phj) in prow.iter_mut().zip(self.ph.iter()) {
                *pj -= s * phj;
            }
            let brow = &mut self.beta[i * m..(i + 1) * m];
            for (bj, &ej) in brow.iter_mut().zip(self.err.iter()) {
                *bj += s * ej;
            }
        }
    }
}

fn json_row(r: &BenchResult, samples_per_iter: Option<f64>) -> Json {
    let mut pairs = vec![
        ("name", Json::Str(r.name.clone())),
        ("mean_ns", Json::Num(r.mean_s * 1e9)),
        ("std_ns", Json::Num(r.std_s * 1e9)),
        ("min_ns", Json::Num(r.min_s * 1e9)),
        ("iters", Json::Num(r.iters as f64)),
    ];
    if let Some(s) = samples_per_iter {
        pairs.push(("samples_per_s", Json::Num(r.per_sec(s))));
    }
    obj(pairs)
}

fn main() {
    let mut rng = Rng64::new(1);
    let cfg = OsElmConfig {
        n_in: 561,
        n_hidden: 128,
        n_out: 6,
        alpha: AlphaKind::Hash,
        ..Default::default()
    };
    let mut model = OsElm::new(cfg, &mut rng, 7);
    let mut xs = Mat::zeros(512, 561);
    let mut labels = Vec::new();
    for r in 0..512 {
        let c = r % 6;
        labels.push(c);
        for j in 0..561 {
            *xs.at_mut(r, j) = rng.normal_ms(if j % 6 == c { 0.5 } else { 0.0 }, 1.0) as f32;
        }
    }
    model.init_batch(&xs, &labels).unwrap();

    let mut rows: Vec<Json> = Vec::new();

    // L3 native hot path — kernel layer vs the pre-kernel scalar baseline,
    // same state, same machine, same process.
    let x = xs.row(0).to_vec();
    let mut baseline = BaselineModel::from(&model);
    let r_pred = bench("native predict (561/128/6)", 10, 200, || {
        std::hint::black_box(model.predict(&x));
    });
    let r_pred_base = bench("baseline predict (561/128/6)", 10, 200, || {
        std::hint::black_box(baseline.predict(&x));
    });
    let r_train = bench("native train_step (561/128/6)", 10, 200, || {
        model.train_step(&x, 3);
    });
    let r_train_base = bench("baseline train_step (561/128/6)", 10, 200, || {
        baseline.train_step(&x, 3);
    });
    let sp_pred = r_pred_base.mean_s / r_pred.mean_s;
    let sp_train = r_train_base.mean_s / r_train.mean_s;
    println!("  -> speedup vs scalar baseline: predict {sp_pred:.2}x, train_step {sp_train:.2}x");
    rows.push(json_row(&r_pred, None));
    rows.push(json_row(&r_pred_base, None));
    rows.push(json_row(&r_train, None));
    rows.push(json_row(&r_train_base, None));

    let mut model256 = OsElm::new(
        OsElmConfig {
            n_hidden: 256,
            ..cfg
        },
        &mut rng,
        7,
    );
    model256.init_batch(&xs, &labels).unwrap();
    let mut baseline256 = BaselineModel::from(&model256);
    let r_train256 = bench("native train_step (561/256/6)", 5, 100, || {
        model256.train_step(&x, 3);
    });
    let r_train256_base = bench("baseline train_step (561/256/6)", 5, 100, || {
        baseline256.train_step(&x, 3);
    });
    let sp_train256 = r_train256_base.mean_s / r_train256.mean_s;
    println!("  -> speedup vs scalar baseline: train_step N=256 {sp_train256:.2}x");
    rows.push(json_row(&r_train256, None));
    rows.push(json_row(&r_train256_base, None));

    let r_batch = bench("native predict_batch 512 (561/128/6)", 3, 30, || {
        std::hint::black_box(model.accuracy(&xs, &labels));
    });
    println!("  -> {:.0} samples/s batched eval", r_batch.per_sec(512.0));
    rows.push(json_row(&r_batch, Some(512.0)));

    // thread-parallel batched predict (row-sharded, bitwise identical)
    let nworkers = odl_har::util::auto_workers(0);
    let r_batch_par = bench(
        &format!("native accuracy_par/{nworkers} 512 (561/128/6)"),
        3,
        30,
        || {
            std::hint::black_box(model.accuracy_par(&xs, &labels, nworkers));
        },
    );
    println!(
        "  -> {:.0} samples/s batched eval ({nworkers} threads, {:.2}x)",
        r_batch_par.per_sec(512.0),
        r_batch.mean_s / r_batch_par.mean_s.max(1e-12)
    );
    rows.push(json_row(&r_batch_par, Some(512.0)));

    let r_init = bench("native init_batch (512 samples, N=128)", 1, 5, || {
        model.init_batch(&xs, &labels).unwrap();
    });
    println!("  -> {:.0} samples/s batch init", r_init.per_sec(512.0));
    rows.push(json_row(&r_init, Some(512.0)));

    // L2/L1 via PJRT (skipped when artifacts are absent)
    if odl_har::runtime::default_artifact_dir().join("manifest.json").exists() {
        let rt = odl_har::runtime::Runtime::open_default().expect("runtime");
        let mut pjrt = odl_har::runtime::PjrtOsElm::new(&rt, 128, 7).expect("pjrt model");
        pjrt.load_state(&model.beta.data, &model.p.data).unwrap();
        let r = bench("pjrt predict_one (561/128/6)", 5, 100, || {
            std::hint::black_box(pjrt.predict(&x).unwrap());
        });
        rows.push(json_row(&r, None));
        let r = bench("pjrt train_step (561/128/6)", 5, 100, || {
            pjrt.train_step(&x, 3).unwrap();
        });
        rows.push(json_row(&r, None));
        let r = bench("pjrt train_stream 512 (scan-fused, K=32)", 1, 10, || {
            pjrt.train_stream(&xs, &labels).unwrap();
        });
        println!(
            "  -> {:.3} ms/sample scan-fused ({:.0} samples/s)",
            r.mean_s * 1e3 / 512.0,
            r.per_sec(512.0)
        );
        rows.push(json_row(&r, Some(512.0)));
        let r = bench("pjrt predict_batch 256 (561/128/6)", 3, 30, || {
            std::hint::black_box(pjrt.accuracy(&xs, &labels).unwrap());
        });
        println!("  -> {:.0} samples/s batched eval", r.per_sec(512.0));
        rows.push(json_row(&r, Some(512.0)));
    } else {
        println!("(skipping PJRT benches: run `make artifacts` first)");
    }

    // fleet event loop (coordination overhead per event)
    let scenario = Scenario {
        n_edges: 4,
        horizon_s: if fast_mode() { 60.0 } else { 300.0 },
        synth: SynthConfig {
            n_features: 561,
            ..Default::default()
        },
        ..Default::default()
    };
    let events = (scenario.horizon_s / scenario.event_period_s) as f64 * 4.0;
    let build = bench("fleet construction (provision 4 edges)", 0, 3, || {
        std::hint::black_box(
            Fleet::new(FleetConfig {
                scenario: scenario.clone(),
                seed: 1,
            })
            .unwrap(),
        );
    });
    rows.push(json_row(&build, None));
    let r = bench("fleet construct + event loop (4 edges)", 0, 3, || {
        let fleet = Fleet::new(FleetConfig {
            scenario: scenario.clone(),
            seed: 1,
        })
        .unwrap();
        std::hint::black_box(fleet.run());
    });
    rows.push(json_row(&r, None));
    let loop_s = (r.mean_s - build.mean_s).max(1e-9);
    println!(
        "  -> {:.0} fleet events/s simulated (loop only, {:.1} us/event)",
        events / loop_s,
        loop_s / events * 1e6
    );

    // machine-readable dump: per-op ns + samples/s + baseline speedups
    let out = obj(vec![
        ("schema", Json::Str("bench_hotpath/v1".into())),
        ("fast_mode", Json::Bool(fast_mode())),
        ("results", Json::Arr(rows)),
        (
            "speedup_vs_baseline",
            obj(vec![
                ("predict_561_128_6", Json::Num(sp_pred)),
                ("train_step_561_128_6", Json::Num(sp_train)),
                ("train_step_561_256_6", Json::Num(sp_train256)),
            ]),
        ),
    ]);
    let path = std::env::var("ODL_BENCH_JSON").unwrap_or_else(|_| "BENCH_hotpath.json".into());
    match std::fs::write(&path, out.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
