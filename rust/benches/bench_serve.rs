//! Serve-path benchmark: an in-process `coordinator::serve` server on an
//! ephemeral port, one client streaming the deterministic loadgen event
//! stream over real TCP, measuring end-to-end request→decision latency
//! (p50/p99) and sustained throughput (events/s).
//!
//! Before timing it asserts the service contracts: every event is
//! applied exactly once (`summary.events == n`, and every applied event
//! either trained or was pruned), and the drained server exits cleanly.
//!
//! Results go to `BENCH_serve.json` (`ODL_BENCH_SERVE_JSON` overrides);
//! `scripts/bench_check.sh` gates `throughput_eps` (higher is better)
//! and `p99_ms` (lower is better) against the rotated baseline.

use odl_har::coordinator::proto::{bits_of, Request, Response};
use odl_har::coordinator::serve::{gen_events, serve_with, ServeConfig};
use odl_har::data::SynthConfig;
use odl_har::util::bench::fast_mode;
use odl_har::util::faults::FaultPlan;
use odl_har::util::json::{obj, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::time::Instant;

fn send(stream: &mut TcpStream, req: &Request) {
    let mut bytes = req.to_line().into_bytes();
    bytes.push(b'\n');
    stream.write_all(&bytes).expect("request write");
}

fn recv(reader: &mut BufReader<TcpStream>) -> Response {
    let mut line = String::new();
    reader.read_line(&mut line).expect("response read");
    Response::parse(line.trim()).expect("response parse")
}

fn percentile_ms(sorted: &[f64], p: f64) -> f64 {
    let idx = ((sorted.len() as f64 * p).ceil() as usize)
        .saturating_sub(1)
        .min(sorted.len() - 1);
    sorted[idx] * 1e3
}

fn main() {
    let cfg = ServeConfig {
        n_hidden: 16,
        warmup: Some(32),
        seed: 11,
        synth: SynthConfig {
            n_features: 12,
            n_classes: 3,
            n_subjects: 2,
            samples_per_cell: 12,
            ..SynthConfig::default()
        },
        ..ServeConfig::default()
    };
    let n = if fast_mode() { 500 } else { 2000 };
    let events = gen_events(&cfg.synth, cfg.data_seed(), cfg.seed, "bench-edge", n);
    println!("serve bench: {n} events over loopback TCP, n_hidden {}", cfg.n_hidden);

    let (tx, rx) = mpsc::channel();
    let server_cfg = cfg.clone();
    let server = std::thread::spawn(move || {
        serve_with(&server_cfg, &FaultPlan::default(), move |addr| {
            tx.send(addr).expect("address handoff");
        })
        .expect("serve failed")
    });
    let addr = rx.recv().expect("server never became ready");

    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).expect("nodelay");
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    send(&mut stream, &Request::Hello { client: "bench-edge".into() });
    match recv(&mut reader) {
        Response::Welcome { restored, .. } => assert!(!restored, "fresh server"),
        other => panic!("expected welcome, got {other:?}"),
    }

    let mut latencies = Vec::with_capacity(events.len());
    let t0 = Instant::now();
    for (seq, (x, label)) in events.iter().enumerate() {
        let req = Request::Event {
            seq: seq as u64,
            label: *label,
            x_bits: bits_of(x),
        };
        let t = Instant::now();
        send(&mut stream, &req);
        match recv(&mut reader) {
            Response::Decision { seq: got, .. } => {
                assert_eq!(got, seq as u64, "acks must come back in order")
            }
            other => panic!("expected a decision for seq {seq}, got {other:?}"),
        }
        latencies.push(t.elapsed().as_secs_f64());
    }
    let total_s = t0.elapsed().as_secs_f64();

    send(&mut stream, &Request::Shutdown);
    match recv(&mut reader) {
        Response::Draining => {}
        other => panic!("expected draining, got {other:?}"),
    }
    drop(stream);
    let summary = server.join().expect("server thread");
    assert_eq!(summary.events, n as u64, "every event applied exactly once");
    assert_eq!(
        summary.trained + summary.skipped,
        summary.events,
        "every applied event either trained or was pruned"
    );
    println!(
        "  contracts hold: {} events = {} trained + {} skipped, clean drain",
        summary.events, summary.trained, summary.skipped
    );

    latencies.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    let throughput_eps = n as f64 / total_s.max(1e-9);
    let p50_ms = percentile_ms(&latencies, 0.50);
    let p99_ms = percentile_ms(&latencies, 0.99);
    println!(
        "  -> {throughput_eps:.0} events/s, p50 {p50_ms:.3} ms, p99 {p99_ms:.3} ms over {total_s:.3} s"
    );

    let out = obj(vec![
        ("schema", Json::Str("bench_serve/v1".into())),
        ("fast_mode", Json::Bool(fast_mode())),
        ("events", Json::Num(n as f64)),
        ("total_s", Json::Num(total_s)),
        ("throughput_eps", Json::Num(throughput_eps)),
        ("p50_ms", Json::Num(p50_ms)),
        ("p99_ms", Json::Num(p99_ms)),
    ]);
    let path =
        std::env::var("ODL_BENCH_SERVE_JSON").unwrap_or_else(|_| "BENCH_serve.json".into());
    match std::fs::write(&path, out.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
