//! Serve-path benchmark: an in-process `coordinator::serve` server on an
//! ephemeral port, driven over real loopback TCP at three operating
//! points:
//!
//! 1. **1 client, unbatched** — the v1 point: end-to-end request→decision
//!    latency (p50/p99) and sustained throughput (events/s), with the
//!    same top-level JSON keys as `bench_serve/v1` baselines.
//! 2. **64 clients** — connection scaling on the shard worker pool, both
//!    unbatched and with `events` frames of 16 (`--batch 16` wire shape).
//! 3. **64 clients, thread-per-connection** — the pre-pool execution
//!    model (`thread_per_conn`), measured in-bench as the baseline the
//!    batched pool must beat: `batch_speedup_64c` is the ratio and is
//!    gated at ≥ 2× by `scripts/bench_check.sh`.
//!
//! Before timing it asserts the service contracts: every event is
//! applied exactly once (`summary.events == n`, and every applied event
//! either trained or was pruned), the drained server exits cleanly, and
//! the pool points keep the server's thread count ≤ workers + 2
//! (measured via /proc/self/status, Linux only).
//!
//! Results go to `BENCH_serve.json` (`ODL_BENCH_SERVE_JSON` overrides);
//! `scripts/bench_check.sh` gates `throughput_eps` (higher is better)
//! and `p99_ms` (lower is better) for the 1-client and 64-client points,
//! plus the absolute `batch_speedup_64c` floor. Peak RSS rides along via
//! `util::bench::peak_rss_bytes`.

use odl_har::coordinator::proto::{bits_of, EventItem, Request, Response};
use odl_har::coordinator::serve::{gen_events, serve_with, ServeConfig};
use odl_har::data::SynthConfig;
use odl_har::util::bench::{fast_mode, peak_rss_bytes};
use odl_har::util::faults::FaultPlan;
use odl_har::util::json::{obj, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::time::Instant;

fn send(stream: &mut TcpStream, req: &Request) {
    let mut bytes = req.to_line().into_bytes();
    bytes.push(b'\n');
    stream.write_all(&bytes).expect("request write");
}

fn recv(reader: &mut BufReader<TcpStream>) -> Response {
    let mut line = String::new();
    reader.read_line(&mut line).expect("response read");
    Response::parse(line.trim()).expect("response parse")
}

fn percentile_ms(sorted: &[f64], p: f64) -> f64 {
    let idx = ((sorted.len() as f64 * p).ceil() as usize)
        .saturating_sub(1)
        .min(sorted.len() - 1);
    sorted[idx] * 1e3
}

/// Live thread count of this process (0 when /proc is unavailable).
fn current_threads() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("Threads:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0)
}

struct Point {
    clients: usize,
    batch: usize,
    events: usize,
    total_s: f64,
    throughput_eps: f64,
    p50_ms: f64,
    p99_ms: f64,
}

impl Point {
    fn to_json(&self) -> Json {
        obj(vec![
            ("clients", Json::Num(self.clients as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("events", Json::Num(self.events as f64)),
            ("total_s", Json::Num(self.total_s)),
            ("throughput_eps", Json::Num(self.throughput_eps)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
        ])
    }
}

/// One bench client: hello, stream `events` in frames of `batch`
/// (plain `event` requests when batch == 1), bye. Returns the per-frame
/// round-trip latencies.
fn drive_client(addr: std::net::SocketAddr, cfg: &ServeConfig, name: &str, n: usize, batch: usize) -> Vec<f64> {
    let events = gen_events(&cfg.synth, cfg.data_seed(), cfg.seed, name, n);
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).expect("nodelay");
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    send(&mut stream, &Request::Hello { client: name.into() });
    match recv(&mut reader) {
        Response::Welcome { restored, .. } => assert!(!restored, "fresh server"),
        other => panic!("expected welcome, got {other:?}"),
    }
    let mut latencies = Vec::with_capacity(n / batch.max(1) + 1);
    let mut next = 0usize;
    while next < events.len() {
        let k = batch.max(1).min(events.len() - next);
        let t = Instant::now();
        if k == 1 {
            let (x, label) = &events[next];
            send(
                &mut stream,
                &Request::Event { seq: next as u64, label: *label, x_bits: bits_of(x) },
            );
            match recv(&mut reader) {
                Response::Decision { seq, .. } => {
                    assert_eq!(seq, next as u64, "acks must come back in order")
                }
                other => panic!("expected a decision for seq {next}, got {other:?}"),
            }
        } else {
            let items = (next..next + k)
                .map(|i| EventItem {
                    seq: i as u64,
                    label: events[i].1,
                    x_bits: bits_of(&events[i].0),
                })
                .collect();
            send(&mut stream, &Request::Events { items });
            match recv(&mut reader) {
                Response::Decisions { items } => {
                    assert_eq!(items.len(), k, "one outcome per frame element")
                }
                other => panic!("expected decisions for seqs {next}.., got {other:?}"),
            }
        }
        latencies.push(t.elapsed().as_secs_f64());
        next += k;
    }
    send(&mut stream, &Request::Bye);
    latencies
}

/// Run one operating point against a fresh server and tear it down.
fn run_point(
    base: &ServeConfig,
    n_clients: usize,
    batch: usize,
    thread_per_conn: bool,
    events_per_client: usize,
) -> Point {
    let mut cfg = base.clone();
    cfg.max_clients = (n_clients * 2).max(8);
    cfg.thread_per_conn = thread_per_conn;
    let n_total = n_clients * events_per_client;

    let threads_before = current_threads();
    let (tx, rx) = mpsc::channel();
    let server_cfg = cfg.clone();
    let server = std::thread::spawn(move || {
        serve_with(&server_cfg, &FaultPlan::default(), move |addr| {
            tx.send(addr).expect("address handoff");
        })
        .expect("serve failed")
    });
    let addr = rx.recv().expect("server never became ready");
    // let the shard pool finish spawning before the thread census
    std::thread::sleep(std::time::Duration::from_millis(50));

    let t0 = Instant::now();
    let mut latencies: Vec<f64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_clients)
            .map(|i| {
                let cfg = &cfg;
                scope.spawn(move || {
                    drive_client(addr, cfg, &format!("bench-edge-{i}"), events_per_client, batch)
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("bench client")).collect()
    });
    let total_s = t0.elapsed().as_secs_f64();

    // the pool's thread count is fixed at startup: with the bench's own
    // client threads joined, the census is the server contribution alone
    let threads_after = current_threads();
    if !thread_per_conn && threads_before > 0 && threads_after > 0 {
        let workers = odl_har::util::auto_workers(cfg.workers).max(1);
        let delta = threads_after.saturating_sub(threads_before);
        assert!(
            delta <= workers + 2,
            "pool point grew {delta} threads; the cap is workers ({workers}) + 2"
        );
    }

    let mut drain = TcpStream::connect(addr).expect("drain connect");
    let mut drain_reader = BufReader::new(drain.try_clone().expect("clone drain"));
    send(&mut drain, &Request::Shutdown);
    match recv(&mut drain_reader) {
        Response::Draining => {}
        other => panic!("expected draining, got {other:?}"),
    }
    drop(drain);
    let summary = server.join().expect("server thread");
    assert_eq!(summary.events, n_total as u64, "every event applied exactly once");
    assert_eq!(
        summary.trained + summary.skipped,
        summary.events,
        "every applied event either trained or was pruned"
    );

    latencies.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    Point {
        clients: n_clients,
        batch,
        events: n_total,
        total_s,
        throughput_eps: n_total as f64 / total_s.max(1e-9),
        p50_ms: percentile_ms(&latencies, 0.50),
        p99_ms: percentile_ms(&latencies, 0.99),
    }
}

fn main() {
    let cfg = ServeConfig {
        n_hidden: 16,
        warmup: Some(32),
        seed: 11,
        synth: SynthConfig {
            n_features: 12,
            n_classes: 3,
            n_subjects: 2,
            samples_per_cell: 12,
            ..SynthConfig::default()
        },
        ..ServeConfig::default()
    };
    let n1 = if fast_mode() { 500 } else { 2000 };
    let per_client = if fast_mode() { 32 } else { 160 };
    println!(
        "serve bench: 1x{n1} + 3x(64x{per_client}) events over loopback TCP, n_hidden {}",
        cfg.n_hidden
    );

    let single = run_point(&cfg, 1, 1, false, n1);
    println!(
        "  1 client          -> {:.0} events/s, p50 {:.3} ms, p99 {:.3} ms",
        single.throughput_eps, single.p50_ms, single.p99_ms
    );
    let c64 = run_point(&cfg, 64, 1, false, per_client);
    println!(
        "  64 clients (pool) -> {:.0} events/s, p99 {:.3} ms",
        c64.throughput_eps, c64.p99_ms
    );
    let c64_b16 = run_point(&cfg, 64, 16, false, per_client);
    println!(
        "  64 clients, batch 16 -> {:.0} events/s, p99 {:.3} ms",
        c64_b16.throughput_eps, c64_b16.p99_ms
    );
    let c64_legacy = run_point(&cfg, 64, 1, true, per_client);
    println!(
        "  64 clients (thread-per-conn baseline) -> {:.0} events/s, p99 {:.3} ms",
        c64_legacy.throughput_eps, c64_legacy.p99_ms
    );

    let batch_speedup_64c = c64_b16.throughput_eps / c64_legacy.throughput_eps.max(1e-9);
    let rss = peak_rss_bytes();
    println!(
        "  -> batch 16 pool vs unbatched thread-per-conn at 64 clients: {batch_speedup_64c:.2}x \
         (gate: >= 2.0), peak RSS {}",
        match rss {
            Some(b) => format!("{:.1} MiB", b as f64 / (1024.0 * 1024.0)),
            None => "n/a".into(),
        }
    );

    let mut fields = vec![
        ("schema", Json::Str("bench_serve/v2".into())),
        ("fast_mode", Json::Bool(fast_mode())),
        // the 1-client point keeps the v1 top-level keys, so rotated v1
        // baselines stay comparable across the schema bump
        ("events", Json::Num(single.events as f64)),
        ("total_s", Json::Num(single.total_s)),
        ("throughput_eps", Json::Num(single.throughput_eps)),
        ("p50_ms", Json::Num(single.p50_ms)),
        ("p99_ms", Json::Num(single.p99_ms)),
        ("c64", c64.to_json()),
        ("c64_b16", c64_b16.to_json()),
        ("c64_legacy", c64_legacy.to_json()),
        ("batch_speedup_64c", Json::Num(batch_speedup_64c)),
    ];
    if let Some(b) = rss {
        // best-effort (absent without procfs); informational, not gated
        fields.push(("peak_rss_bytes", Json::Num(b as f64)));
    }
    let out = obj(fields);
    let path =
        std::env::var("ODL_BENCH_SERVE_JSON").unwrap_or_else(|_| "BENCH_serve.json".into());
    match std::fs::write(&path, out.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
