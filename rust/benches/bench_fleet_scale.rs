//! Fleet-scale benchmark: the sequential event loop vs the sharded
//! `Fleet::run_parallel` engine at 8 / 64 / 256 edges, plus sequential vs
//! sharded **provisioning** (`Fleet::new` vs `Fleet::new_parallel` with
//! [`PROVISION_WORKERS`] workers).
//!
//! Before timing anything each size asserts the engine contracts — the
//! parallel report must be bitwise identical to the sequential one, and a
//! parallel-provisioned fleet must produce that same report — so a
//! sharding regression can never produce a "fast but wrong" number.
//! Construction (data generation + provisioning all edges) is timed
//! separately and subtracted, so `speedup_loop` isolates the event-loop
//! scaling the parallel engine is responsible for; `speedup_total`
//! includes construction; `provision_speedup` is the construction-phase
//! win of sharded per-edge `init_batch` (the PR-3 acceptance bar is ≥ 3×
//! at 256 edges on a ≥ 4-core host).
//!
//! A fourth point exercises the **million-edge engine path**: a
//! 100 000-edge fleet in `fleet.metrics = "aggregate"` mode (time-wheel
//! event loop, O(1) sketched report). Before timing it, a small fleet
//! asserts aggregate totals bitwise-match the full-mode report's sums, so
//! the cheap mode can never drift from the accounted one. The tracked
//! metric is `events_per_sec` at 100k edges (plus best-effort peak RSS).
//!
//! Results go to `BENCH_fleet.json` (`ODL_BENCH_FLEET_JSON` overrides);
//! `scripts/bench_check.sh` diffs them against the previous accepted run.

use odl_har::coordinator::fleet::{Fleet, FleetConfig, Scenario};
use odl_har::coordinator::MetricsMode;
use odl_har::data::SynthConfig;
use odl_har::util::bench::{bench, fast_mode, fmt_time, peak_rss_bytes};
use odl_har::util::json::{obj, Json};
use std::time::Instant;

/// Worker count for the provisioning-speedup rows (fixed, not
/// autodetected, so the tracked metric means the same thing on every
/// machine; the achieved ratio still saturates at the core count).
const PROVISION_WORKERS: usize = 8;

fn scenario(n_edges: usize) -> Scenario {
    Scenario {
        n_edges,
        n_hidden: 32,
        event_period_s: 1.0,
        horizon_s: if fast_mode() { 90.0 } else { 240.0 },
        drift_at_s: 30.0,
        train_target: 60,
        eval_period_s: 60.0,
        eval_samples: 32,
        synth: SynthConfig {
            n_features: 40,
            n_classes: 4,
            n_subjects: 30,
            samples_per_cell: 6,
            proto_sigma: 1.1,
            confuse_frac: 0.04,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// The 100k-edge scenario: every per-edge cost pared down (tiny feature
/// dim, tiny hidden layer, small pool, no eval windows) so the bench
/// measures the *engine* — bucket walk, dispatch, sketch folds — not the
/// linear algebra.
fn scale_scenario(n_edges: usize) -> Scenario {
    Scenario {
        n_edges,
        n_hidden: 8,
        event_period_s: 1.0,
        horizon_s: if fast_mode() { 10.0 } else { 30.0 },
        drift_at_s: 1.0e9, // never: throughput point measures steady state
        train_target: 20,
        metrics: MetricsMode::Aggregate,
        synth: SynthConfig {
            n_features: 16,
            n_classes: 4,
            n_subjects: 30,
            samples_per_cell: 4,
            proto_sigma: 1.1,
            confuse_frac: 0.04,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Consistency gate for the aggregate point: at a small size, the
/// aggregate report's counters and energy must bitwise-match the sums of
/// the full-mode per-edge rows (trajectories are identical by contract —
/// `metrics` is a memory knob, not a numerics knob).
fn assert_aggregate_matches_full(workers: usize) {
    let mut sc = scale_scenario(512);
    sc.metrics = MetricsMode::Full;
    let full = Fleet::new_parallel(
        FleetConfig {
            scenario: sc.clone(),
            seed: 7,
        },
        workers,
    )
    .unwrap()
    .run_parallel(workers);
    sc.metrics = MetricsMode::Aggregate;
    let agg_report = Fleet::new_parallel(FleetConfig { scenario: sc, seed: 7 }, workers)
        .unwrap()
        .run_parallel(workers);
    let agg = agg_report
        .aggregate
        .as_ref()
        .expect("aggregate mode must produce a FleetAggregate");
    assert_eq!(agg.n_edges as usize, full.per_edge.len());
    assert_eq!(agg.events, full.per_edge.iter().map(|m| m.events).sum::<u64>());
    assert_eq!(agg.trained, full.per_edge.iter().map(|m| m.trained).sum::<u64>());
    assert_eq!(agg.total_queries, full.total_queries());
    assert_eq!(agg_report.teacher_queries, full.teacher_queries);
    assert_eq!(agg_report.channel_attempts, full.channel_attempts);
    assert_eq!(
        agg.total_energy_mj.to_bits(),
        full.total_energy_mj().to_bits(),
        "aggregate energy diverged from full-mode sum"
    );
}

fn main() {
    let workers = odl_har::util::auto_workers(0);
    println!(
        "fleet scale: sequential vs run_parallel({workers}) — reports asserted bitwise equal per size"
    );

    let mut rows: Vec<Json> = Vec::new();
    for &edges in &[8usize, 64, 256] {
        let sc = scenario(edges);

        // determinism gates before timing: run sharding and construction
        // sharding must both reproduce the sequential report bit for bit
        let seq_report = Fleet::new(FleetConfig {
            scenario: sc.clone(),
            seed: 7,
        })
        .unwrap()
        .run();
        let par_report = Fleet::new(FleetConfig {
            scenario: sc.clone(),
            seed: 7,
        })
        .unwrap()
        .run_parallel(workers);
        assert!(
            seq_report.bitwise_eq(&par_report),
            "parallel report diverged from sequential at {edges} edges"
        );
        let prov_report = Fleet::new_parallel(
            FleetConfig {
                scenario: sc.clone(),
                seed: 7,
            },
            PROVISION_WORKERS,
        )
        .unwrap()
        .run();
        assert!(
            seq_report.bitwise_eq(&prov_report),
            "parallel provisioning diverged from sequential at {edges} edges"
        );

        // never fewer than 3 iterations: seq_loop_s / speedup_loop feed
        // the 10% regression gate in scripts/bench_check.sh, and a
        // single-sample measurement could rotate a noise spike in as the
        // accepted baseline (fast mode shrinks the horizon instead)
        let iters = if fast_mode() { 3 } else { 5 };
        let r_build = bench(&format!("fleet build {edges:>3} edges"), 1, iters, || {
            std::hint::black_box(
                Fleet::new(FleetConfig {
                    scenario: sc.clone(),
                    seed: 7,
                })
                .unwrap(),
            );
        });
        let r_build_par = bench(
            &format!("fleet build/{PROVISION_WORKERS} {edges:>3} edges"),
            1,
            iters,
            || {
                std::hint::black_box(
                    Fleet::new_parallel(
                        FleetConfig {
                            scenario: sc.clone(),
                            seed: 7,
                        },
                        PROVISION_WORKERS,
                    )
                    .unwrap(),
                );
            },
        );
        let r_seq = bench(&format!("fleet seq   {edges:>3} edges"), 1, iters, || {
            let f = Fleet::new(FleetConfig {
                scenario: sc.clone(),
                seed: 7,
            })
            .unwrap();
            std::hint::black_box(f.run());
        });
        let r_par = bench(
            &format!("fleet par/{workers} {edges:>3} edges"),
            1,
            iters,
            || {
                let f = Fleet::new(FleetConfig {
                    scenario: sc.clone(),
                    seed: 7,
                })
                .unwrap();
                std::hint::black_box(f.run_parallel(workers));
            },
        );

        // floor the construction subtraction at 5 % of the raw mean: if
        // build noise swamps the loop time the ratio degrades gracefully
        // instead of exploding toward 1e9 and poisoning the baseline
        let seq_loop = (r_seq.mean_s - r_build.mean_s).max(r_seq.mean_s * 0.05);
        let par_loop = (r_par.mean_s - r_build.mean_s).max(r_par.mean_s * 0.05);
        let speedup_loop = seq_loop / par_loop;
        let speedup_total = r_seq.mean_s / r_par.mean_s.max(1e-9);
        let provision_speedup = r_build.mean_s / r_build_par.mean_s.max(1e-9);
        println!(
            "  -> {edges} edges: event loop {speedup_loop:.2}x ({seq_loop:.3}s -> {par_loop:.3}s), end-to-end {speedup_total:.2}x with {workers} workers"
        );
        println!(
            "  -> {edges} edges: provisioning {provision_speedup:.2}x ({:.1} ms -> {:.1} ms) with {PROVISION_WORKERS} workers",
            r_build.mean_s * 1e3,
            r_build_par.mean_s * 1e3
        );
        rows.push(obj(vec![
            ("edges", Json::Num(edges as f64)),
            ("workers", Json::Num(workers as f64)),
            ("build_mean_s", Json::Num(r_build.mean_s)),
            ("seq_mean_s", Json::Num(r_seq.mean_s)),
            ("par_mean_s", Json::Num(r_par.mean_s)),
            ("seq_loop_s", Json::Num(seq_loop)),
            ("par_loop_s", Json::Num(par_loop)),
            ("speedup_loop", Json::Num(speedup_loop)),
            ("speedup_total", Json::Num(speedup_total)),
            // construction split: provision_ms is what Fleet::new_parallel
            // costs now; provision_seq_ms the old sequential walk
            ("provision_ms", Json::Num(r_build_par.mean_s * 1e3)),
            ("provision_seq_ms", Json::Num(r_build.mean_s * 1e3)),
            ("provision_workers", Json::Num(PROVISION_WORKERS as f64)),
            ("provision_speedup", Json::Num(provision_speedup)),
        ]));
    }

    // --- 100k-edge aggregate point (time wheel + O(1) sketched report) ---
    // gate first: the cheap mode must match the accounted one bit for bit
    assert_aggregate_matches_full(workers);
    const SCALE_EDGES: usize = 100_000;
    let sc = scale_scenario(SCALE_EDGES);
    // one build + best-of-N runs, timed with Instant instead of bench():
    // run_parallel consumes the fleet, and at this size a rebuild per
    // iteration would dominate the wall clock
    let runs = if fast_mode() { 1 } else { 2 };
    let mut build_s = 0.0f64;
    let mut best_run_s = f64::INFINITY;
    let mut events = 0u64;
    for _ in 0..runs {
        let t0 = Instant::now();
        let fleet = Fleet::new_parallel(
            FleetConfig {
                scenario: sc.clone(),
                seed: 7,
            },
            workers,
        )
        .unwrap();
        build_s = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let report = fleet.run_parallel(workers);
        let run_s = t1.elapsed().as_secs_f64();
        let agg = report.aggregate.as_ref().expect("aggregate report");
        events = agg.events;
        assert!(
            report.per_edge.is_empty(),
            "aggregate mode must not materialize per-edge rows"
        );
        best_run_s = best_run_s.min(run_s);
    }
    let events_per_sec = events as f64 / best_run_s.max(1e-9);
    let peak_rss = peak_rss_bytes();
    println!(
        "  -> {SCALE_EDGES} edges (aggregate): {events} events in {} — {:.0} events/s, build {}, peak RSS {}",
        fmt_time(best_run_s),
        events_per_sec,
        fmt_time(build_s),
        match peak_rss {
            Some(b) => format!("{:.0} MiB", b as f64 / (1024.0 * 1024.0)),
            None => "n/a".into(),
        }
    );
    let mut scale_row = vec![
        ("edges", Json::Num(SCALE_EDGES as f64)),
        ("workers", Json::Num(workers as f64)),
        ("metrics", Json::Str("aggregate".into())),
        ("events", Json::Num(events as f64)),
        ("build_s", Json::Num(build_s)),
        ("run_s", Json::Num(best_run_s)),
        ("events_per_sec", Json::Num(events_per_sec)),
    ];
    if let Some(b) = peak_rss {
        // best-effort (absent without procfs); informational, not gated
        scale_row.push(("peak_rss_bytes", Json::Num(b as f64)));
    }
    rows.push(obj(scale_row));

    let out = obj(vec![
        ("schema", Json::Str("bench_fleet/v1".into())),
        ("fast_mode", Json::Bool(fast_mode())),
        ("workers", Json::Num(workers as f64)),
        ("results", Json::Arr(rows)),
    ]);
    let path =
        std::env::var("ODL_BENCH_FLEET_JSON").unwrap_or_else(|_| "BENCH_fleet.json".into());
    match std::fs::write(&path, out.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
