//! Bench + regeneration for Figure 4 (training-mode power vs θ at three
//! event rates, compute/comm split, auto-θ reductions).

use odl_har::exp::{fig3, fig4};
use odl_har::pruning::Metric;
use odl_har::util::bench::bench_trials;

fn main() {
    let trials = bench_trials();
    let points = fig3::sweep(trials, Metric::P1P2).expect("sweep");
    let (table, _) = fig4::run_fig(&points).expect("fig4");
    println!("{}", table.render());
    for (period, red) in fig4::auto_reductions(&points) {
        let paper = match period as u64 {
            1 => 49.4,
            5 => 34.7,
            _ => 25.2,
        };
        println!("Auto reduction @ 1/{period:.0}s: {red:.1} % (paper {paper} %)");
    }
    let reductions = fig4::auto_reductions(&points);
    assert!(reductions[0].1 > reductions[2].1, "reductions must shrink with period");
}
