//! Scenario-sweep benchmark: a TOML-shaped grid (seeds × thetas × edge
//! counts) run the naive way — one full `Fleet::new` per cell, back to
//! back — vs the memoized `coordinator::sweep` engine (shared artifacts +
//! per-fleet shuffles memoized, built lazily, dropped at last use, cells
//! fanned over the shared executor), plus the resume path.
//!
//! Before timing anything it asserts the engine contracts:
//!
//! * memoization actually engages (`artifact_builds == 1`,
//!   `artifact_hits == cells − 1` for the pinned data seed; one shuffle
//!   build per simulation seed);
//! * every memoized cell report is **bitwise identical** to the
//!   individually constructed fleet for the same scenario;
//! * a sweep resumed from a truncated results file finishes **byte
//!   identical** to the uninterrupted file;
//! * a 3-way `--shard` split, merged, is **byte identical** to the
//!   single-process file;
//! * the edge-state memo (provisioned cores shared across cells that
//!   differ only in `n_edges`) is bitwise invisible on an
//!   `edge_counts`-heavy grid — then that grid is timed memo-off vs
//!   memo-on (`edge_memo_speedup`, plus the plan-derived
//!   `edge_hit_rate`).
//!
//! Results go to `BENCH_sweep.json` (`ODL_BENCH_SWEEP_JSON` overrides);
//! `scripts/bench_check.sh` gates `memo_speedup` / `edge_memo_speedup`
//! regressions > 10 %, `resume_overhead_frac` (a resumed-complete run
//! must be ~free), the absolute edge-memo gates (`edge_hit_rate` ≥
//! 0.5, and `edge_memo_speedup` ≥ 0.9 — the memo must be a wall-clock
//! win, floor held with the shared 10 % noise tolerance), and
//! `supervise_overhead_frac` ≤ 0.15 — the fault-free self-healing
//! supervisor (`--shard auto`: child processes + heartbeat polling +
//! auto-merge, see `coordinator::supervise`) must cost ≤ 15 % over a
//! single-process run of the same grid.

use odl_har::config;
use odl_har::coordinator::fleet::{DetectorKind, Fleet, FleetConfig, Scenario};
use odl_har::coordinator::supervise::{
    shard_out_paths, supervise, ProcessLauncher, SuperviseConfig, SuperviseStatus,
};
use odl_har::coordinator::sweep::{
    merge_shard_files, resume_sweep_to_file, run_planned_to_file, run_shard_to_file, run_sweep,
    run_sweep_to_file, ShardSpec, SweepSpec,
};
use odl_har::data::SynthConfig;
use odl_har::util::bench::{bench, fast_mode};
use odl_har::util::json::{obj, Json};

/// The supervised grid must be TOML-declared: child processes re-derive
/// the spec (and grid hash) from this config file, so every knob has to
/// round-trip through the config parser. 8 cells over one pinned data
/// build.
fn supervise_toml() -> String {
    format!(
        "[fleet]\n\
         n_edges = 2\n\
         n_hidden = 24\n\
         horizon_s = {}\n\
         drift_at_s = 20\n\
         train_target = 40\n\
         seed = 1\n\
         data_seed = 190\n\
         [data]\n\
         n_features = 32\n\
         n_classes = 4\n\
         samples_per_cell = 5\n\
         [sweep]\n\
         seeds = [1, 2]\n\
         thetas = [\"auto\", 0.2]\n\
         edge_counts = [2]\n\
         detectors = [\"oracle\"]\n\
         n_hiddens = [24]\n\
         loss_probs = [0.0, 0.2]\n\
         teacher_errors = [0.0]\n",
        if fast_mode() { 60 } else { 120 }
    )
}

fn base_scenario() -> Scenario {
    Scenario {
        n_edges: 4,
        n_hidden: 24,
        event_period_s: 1.0,
        horizon_s: if fast_mode() { 60.0 } else { 150.0 },
        drift_at_s: 20.0,
        train_target: 40,
        data_seed: Some(0x5EED_CAFE),
        synth: SynthConfig {
            n_features: 32,
            n_classes: 4,
            n_subjects: 30,
            samples_per_cell: 5,
            proto_sigma: 1.1,
            confuse_frac: 0.04,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn spec(workers: usize) -> SweepSpec {
    let base = base_scenario();
    SweepSpec {
        seeds: vec![1, 2],
        thetas: vec![None, Some(0.2)],
        edge_counts: vec![4, 8],
        detectors: vec![DetectorKind::Oracle],
        n_hiddens: vec![base.n_hidden],
        loss_probs: vec![base.channel.loss_prob],
        teacher_errors: vec![base.teacher_error],
        workers,
        record_pca: false,
        memo_edge_state: true,
        base,
    }
}

/// An `edge_counts`-heavy grid where per-edge `init_batch` dominates —
/// the edge-state memo's target workload: one seed, one hidden width,
/// fleets of growing size, so memo-off provisions Σ n_edges cores per
/// theta while memo-on builds max(n_edges) once and lends them out.
fn edge_spec(workers: usize, memo: bool) -> SweepSpec {
    let mut base = base_scenario();
    base.n_hidden = 64;
    base.horizon_s = if fast_mode() { 30.0 } else { 80.0 };
    SweepSpec {
        seeds: vec![1],
        thetas: vec![None, Some(0.2), Some(0.3)],
        edge_counts: vec![4, 8, 16],
        detectors: vec![DetectorKind::Oracle],
        n_hiddens: vec![base.n_hidden],
        loss_probs: vec![base.channel.loss_prob],
        teacher_errors: vec![base.teacher_error],
        workers,
        record_pca: false,
        memo_edge_state: memo,
        base,
    }
}

fn run_naive(spec: &SweepSpec) -> Vec<odl_har::coordinator::FleetReport> {
    spec.cells()
        .into_iter()
        .map(|(cell, sc)| {
            Fleet::new(FleetConfig {
                scenario: sc,
                seed: cell.seed,
            })
            .unwrap()
            .run()
        })
        .collect()
}

fn main() {
    let workers = odl_har::util::auto_workers(0);
    let spec = spec(workers);
    let n_cells = spec.cells().len();
    println!(
        "sweep grid: {n_cells} cells, memoized engine with {workers} workers vs naive per-cell construction"
    );

    // contract gates before timing
    let outcome = run_sweep(&spec).expect("sweep failed");
    assert_eq!(outcome.stats.cells, n_cells);
    assert_eq!(
        outcome.stats.artifact_builds, 1,
        "pinned data seed must fit the data exactly once"
    );
    assert!(
        outcome.stats.artifact_hits == n_cells - 1 && outcome.stats.artifact_hits > 0,
        "memoization must hit every remaining cell (hits {})",
        outcome.stats.artifact_hits
    );
    assert_eq!(
        outcome.stats.shuffle_builds, 2,
        "the per-fleet shuffle must memoize per (data key, seed)"
    );
    assert_eq!(outcome.stats.shuffle_hits, n_cells - 2);
    let naive_reports = run_naive(&spec);
    for ((cell, memo), naive) in outcome.reports.iter().zip(&naive_reports) {
        assert!(
            memo.bitwise_eq(naive),
            "cell {} diverged from the individually constructed fleet",
            cell.index
        );
    }
    println!(
        "  contracts hold: builds {}, hits {}, shuffles {}+{}, all {} reports bitwise equal",
        outcome.stats.artifact_builds,
        outcome.stats.artifact_hits,
        outcome.stats.shuffle_builds,
        outcome.stats.shuffle_hits,
        n_cells
    );

    // resume contract: truncate mid-grid, resume, compare bytes
    let dir = std::env::temp_dir().join("odl_har_bench_sweep");
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("sweep.jsonl");
    run_sweep_to_file(&spec, &path).expect("sweep to file failed");
    let full = std::fs::read_to_string(&path).expect("read results");
    let cut: String = full.lines().take(4).map(|l| format!("{l}\n")).collect();
    std::fs::write(&path, cut).expect("truncate results");
    let resumed = resume_sweep_to_file(&spec, &path).expect("resume failed");
    assert_eq!(
        (resumed.skipped, resumed.ran),
        (3, n_cells - 3),
        "resume must keep the 3-row prefix and run the rest"
    );
    assert_eq!(
        std::fs::read_to_string(&path).expect("read resumed results"),
        full,
        "resumed file must be byte-identical to the uninterrupted run"
    );
    println!("  resume contract holds: 3 kept + {} rerun, bytes identical", n_cells - 3);

    // shard/merge contract: a 3-way split of the same grid, merged in
    // scrambled order, must reproduce the single-process file byte for
    // byte (the process-level fan-out protocol)
    let plan = spec.plan();
    let mut shard_paths = Vec::new();
    for index in 1..=3usize {
        let p = dir.join(format!("shard_{index}.jsonl"));
        run_shard_to_file(&spec, &plan, ShardSpec { index, of: 3 }, &p).expect("shard failed");
        shard_paths.push(p);
    }
    shard_paths.reverse();
    let merged = dir.join("merged.jsonl");
    merge_shard_files(&plan, &shard_paths, &merged).expect("merge failed");
    assert_eq!(
        std::fs::read_to_string(&merged).expect("read merged results"),
        full,
        "merged shard set must be byte-identical to the single-process run"
    );
    println!("  shard contract holds: 3-way split merges byte-identical");

    // edge-state memo contract: an edge_counts-heavy grid must be
    // bitwise identical with the memo on and off before we time it
    let e_on = run_sweep(&edge_spec(workers, true)).expect("edge sweep failed");
    let e_off = run_sweep(&edge_spec(workers, false)).expect("edge sweep failed");
    for ((cell, a), (_, b)) in e_on.reports.iter().zip(&e_off.reports) {
        assert!(
            a.bitwise_eq(b),
            "edge grid cell {} diverged with the memo on",
            cell.index
        );
    }
    let edge_total = e_on.stats.edge_builds + e_on.stats.edge_hits;
    assert_eq!(e_off.stats.edge_hits, 0);
    assert_eq!(e_off.stats.edge_builds, edge_total);
    assert!(
        e_on.stats.edge_hits > e_on.stats.edge_builds,
        "edge memo must hit more than it builds on this grid ({} builds, {} hits)",
        e_on.stats.edge_builds,
        e_on.stats.edge_hits
    );
    let edge_hit_rate = e_on.stats.edge_hits as f64 / edge_total.max(1) as f64;
    println!(
        "  edge-state memo contract holds: {} builds + {} hits (hit rate {:.2}), bitwise equal to memo off",
        e_on.stats.edge_builds, e_on.stats.edge_hits, edge_hit_rate
    );

    let iters = if fast_mode() { 3 } else { 5 };
    let r_naive = bench(&format!("sweep naive {n_cells:>2} cells"), 1, iters, || {
        std::hint::black_box(run_naive(&spec));
    });
    let r_memo = bench(
        &format!("sweep memo/{workers} {n_cells:>2} cells"),
        1,
        iters,
        || {
            std::hint::black_box(run_sweep(&spec).expect("sweep failed"));
        },
    );
    let memo_speedup = r_naive.mean_s / r_memo.mean_s.max(1e-9);
    println!(
        "  -> grid {memo_speedup:.2}x ({:.3}s -> {:.3}s) with memoized artifacts + {workers} workers",
        r_naive.mean_s, r_memo.mean_s
    );

    // resume overhead: a full file run vs resuming the already complete
    // file (parse + verify + write nothing). The latter must be ~free.
    let r_file = bench(
        &format!("sweep to-file {n_cells:>2} cells"),
        1,
        iters,
        || {
            std::hint::black_box(run_sweep_to_file(&spec, &path).expect("sweep to file failed"));
        },
    );
    let r_resume = bench(
        &format!("sweep resume complete {n_cells:>2} cells"),
        1,
        iters,
        || {
            let out = resume_sweep_to_file(&spec, &path).expect("resume failed");
            assert!(out.already_complete, "complete file must resume as a no-op");
            std::hint::black_box(out);
        },
    );
    let resume_overhead_frac = r_resume.mean_s / r_file.mean_s.max(1e-9);
    println!(
        "  -> resume of a complete file: {:.1} ms = {:.3} of a full file run",
        r_resume.mean_s * 1e3,
        resume_overhead_frac
    );
    let _ = std::fs::remove_dir_all(&dir);

    // edge-state memo wall clock: the same edge_counts-heavy grid with
    // shared provisioned cores vs per-cell re-provisioning
    let n_edge_cells = edge_spec(workers, true).cells().len();
    let r_edge_off = bench(
        &format!("edge grid memo-off {n_edge_cells:>2} cells"),
        1,
        iters,
        || {
            std::hint::black_box(run_sweep(&edge_spec(workers, false)).expect("sweep failed"));
        },
    );
    let r_edge_on = bench(
        &format!("edge grid memo-on  {n_edge_cells:>2} cells"),
        1,
        iters,
        || {
            std::hint::black_box(run_sweep(&edge_spec(workers, true)).expect("sweep failed"));
        },
    );
    let edge_memo_speedup = r_edge_off.mean_s / r_edge_on.mean_s.max(1e-9);
    println!(
        "  -> edge-state memo {edge_memo_speedup:.2}x ({:.3}s -> {:.3}s) on the edge_counts-heavy grid",
        r_edge_off.mean_s, r_edge_on.mean_s
    );

    // supervise overhead: the fault-free `--shard auto` path (2 child
    // processes, heartbeat polling, auto-merge) vs a single-process run
    // of the same TOML-declared grid with the same total worker budget
    let sdir = std::env::temp_dir().join("odl_har_bench_supervise");
    std::fs::create_dir_all(&sdir).expect("temp dir");
    let toml_text = supervise_toml();
    let cfg_path = sdir.join("grid.toml");
    std::fs::write(&cfg_path, &toml_text).expect("write config");
    let mut sspec = config::sweep_from_str(&toml_text).expect("bench grid must parse");
    sspec.workers = workers;
    let splan = sspec.plan();
    let n_sup_cells = splan.cells.len();
    let single_path = sdir.join("single.jsonl");
    run_planned_to_file(&sspec, &splan, &single_path).expect("single-process run failed");
    let single_bytes = std::fs::read(&single_path).expect("read single-process results");
    let scfg = SuperviseConfig {
        shards: 2,
        workers_per_shard: (workers / 2).max(1),
        poll_ms: 5,
        ..Default::default()
    };
    let launcher = ProcessLauncher {
        exe: std::path::PathBuf::from(env!("CARGO_BIN_EXE_odl-har")),
        config_path: cfg_path.clone(),
    };
    let merged = sdir.join("merged.jsonl");
    let shard_files = shard_out_paths(&merged, 2);
    // contract before timing: a supervised run completes and its merge is
    // byte-identical to the single-process file
    let run_supervised = || {
        for p in &shard_files {
            let _ = std::fs::remove_file(p);
        }
        let outcome =
            supervise(&splan, &scfg, &launcher, &shard_files, Some(&merged)).expect("supervise");
        assert_eq!(
            outcome.status,
            SuperviseStatus::Complete,
            "fault-free supervision must complete: {:?}",
            outcome.shards
        );
    };
    run_supervised();
    assert_eq!(
        std::fs::read(&merged).expect("read merged results"),
        single_bytes,
        "supervised auto-merge must be byte-identical to the single-process run"
    );
    println!("  supervise contract holds: 2 children auto-merge byte-identical");
    let r_sup_single = bench(
        &format!("supervise baseline  {n_sup_cells:>2} cells"),
        1,
        iters,
        || {
            std::hint::black_box(
                run_planned_to_file(&sspec, &splan, &single_path).expect("run failed"),
            );
        },
    );
    let r_sup = bench(
        &format!("supervise 2 shards  {n_sup_cells:>2} cells"),
        1,
        iters,
        run_supervised,
    );
    let supervise_overhead_frac = r_sup.mean_s / r_sup_single.mean_s.max(1e-9) - 1.0;
    println!(
        "  -> supervised run: {:.3}s vs {:.3}s single-process = {:+.3} overhead frac",
        r_sup.mean_s, r_sup_single.mean_s, supervise_overhead_frac
    );
    let _ = std::fs::remove_dir_all(&sdir);

    let out = obj(vec![
        ("schema", Json::Str("bench_sweep/v4".into())),
        ("fast_mode", Json::Bool(fast_mode())),
        ("workers", Json::Num(workers as f64)),
        ("cells", Json::Num(n_cells as f64)),
        (
            "artifact_builds",
            Json::Num(outcome.stats.artifact_builds as f64),
        ),
        (
            "artifact_hits",
            Json::Num(outcome.stats.artifact_hits as f64),
        ),
        (
            "shuffle_builds",
            Json::Num(outcome.stats.shuffle_builds as f64),
        ),
        (
            "shuffle_hits",
            Json::Num(outcome.stats.shuffle_hits as f64),
        ),
        ("naive_s", Json::Num(r_naive.mean_s)),
        ("memo_s", Json::Num(r_memo.mean_s)),
        ("memo_speedup", Json::Num(memo_speedup)),
        ("file_s", Json::Num(r_file.mean_s)),
        ("resume_complete_s", Json::Num(r_resume.mean_s)),
        ("resume_overhead_frac", Json::Num(resume_overhead_frac)),
        ("edge_cells", Json::Num(n_edge_cells as f64)),
        ("edge_builds", Json::Num(e_on.stats.edge_builds as f64)),
        ("edge_hits", Json::Num(e_on.stats.edge_hits as f64)),
        ("edge_hit_rate", Json::Num(edge_hit_rate)),
        ("edge_off_s", Json::Num(r_edge_off.mean_s)),
        ("edge_memo_s", Json::Num(r_edge_on.mean_s)),
        ("edge_memo_speedup", Json::Num(edge_memo_speedup)),
        ("supervise_cells", Json::Num(n_sup_cells as f64)),
        ("supervise_single_s", Json::Num(r_sup_single.mean_s)),
        ("supervise_s", Json::Num(r_sup.mean_s)),
        (
            "supervise_overhead_frac",
            Json::Num(supervise_overhead_frac),
        ),
    ]);
    let path =
        std::env::var("ODL_BENCH_SWEEP_JSON").unwrap_or_else(|_| "BENCH_sweep.json".into());
    match std::fs::write(&path, out.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
