"""L1 kernel correctness: Pallas (interpret=True) vs the pure-jnp oracle.

Hypothesis sweeps shapes and seeds; assert_allclose against ref.py is the
core correctness signal of the compile path.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import hash_elm, oselm, predict as predict_k, ref

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def rand(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


# --- xorshift / alpha --------------------------------------------------------


class TestXorshift:
    def test_stream_full_period_prefix(self):
        s = ref.xorshift16_stream(1, 1000)
        assert len(set(s.tolist())) == 1000  # no repeats inside the period

    def test_stream_first_value(self):
        # spec pin: state 1 -> 0x8181 (matches rust xorshift.rs test)
        assert ref.xorshift16_stream(1, 1)[0] == 0x8181

    def test_zero_seed_remapped(self):
        a = ref.xorshift16_stream(0, 4)
        b = ref.xorshift16_stream(ref.SEED_REMAP, 4)
        assert (a == b).all()

    @given(seed=st.integers(0, 0xFFFF))
    def test_counter_alpha_jnp_matches_numpy(self, seed):
        a_np = ref.counter_alpha_np(seed, 12, 6, 1.0)
        a_j = np.asarray(ref.counter_alpha(seed, 12, 6, 1.0))
        assert_allclose(a_np, a_j, rtol=0, atol=0)

    @given(seed=st.integers(0, 0xFFFF))
    def test_counter_alpha_in_range(self, seed):
        a = ref.counter_alpha_np(seed, 20, 10, 1.0)
        assert (a >= -1.0).all() and (a < 1.0).all()

    def test_counter_alpha_stride_decorrelated(self):
        a = ref.counter_alpha_np(3, 561, 128, 1.0).reshape(-1)
        mean, var = a.mean(), a.var()
        for lag in (1, 64, 128, 561):
            r = ((a[:-lag] - mean) * (a[lag:] - mean)).mean() / var
            assert abs(r) < 0.02, f"lag {lag}: {r}"


# --- hash_hidden kernel ------------------------------------------------------


class TestHashHidden:
    @given(
        n=st.sampled_from([8, 57, 128, 561]),
        n_hidden=st.sampled_from([8, 32, 128, 200, 256]),
        b=st.sampled_from([1, 3, 8]),
        seed=st.integers(0, 0xFFFF),
    )
    def test_matches_ref(self, n, n_hidden, b, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, b, n)
        got = np.asarray(hash_elm.hash_hidden(x, seed, n_hidden))
        want = np.asarray(ref.hidden_ref(x, seed, n_hidden))
        assert got.shape == (b, n_hidden)
        assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_output_in_unit_interval(self):
        # sigmoid saturates to exactly 1.0 in f32 for large inputs — the
        # closed interval is the correct invariant.
        rng = np.random.default_rng(0)
        h = np.asarray(hash_elm.hash_hidden(rand(rng, 4, 561) * 10, 1, 128))
        assert (h >= 0).all() and (h <= 1).all()
        assert h.std() > 0.1  # and it is not collapsed

    def test_seed_changes_output(self):
        rng = np.random.default_rng(0)
        x = rand(rng, 2, 64)
        a = np.asarray(hash_elm.hash_hidden(x, 1, 32))
        b = np.asarray(hash_elm.hash_hidden(x, 2, 32))
        assert np.abs(a - b).max() > 1e-3

    @given(seed=st.integers(0, 0xFFFF))
    def test_stored_hidden_matches_ref(self, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, 4, 40)
        alpha = rand(rng, 40, 16) * 0.2
        got = np.asarray(hash_elm.stored_hidden(x, alpha))
        want = np.asarray(ref.hidden_stored_ref(x, alpha))
        assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_stored_hidden_nontile_hidden(self):
        # n_hidden = 200 is not a multiple of TILE_N=128 → padded path
        rng = np.random.default_rng(3)
        x = rand(rng, 2, 30)
        alpha = rand(rng, 30, 200) * 0.1
        got = np.asarray(hash_elm.stored_hidden(x, alpha))
        want = np.asarray(ref.hidden_stored_ref(x, alpha))
        assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# --- oselm update kernels ----------------------------------------------------


class TestOselmUpdate:
    @given(
        n_hidden=st.sampled_from([8, 32, 128, 256]),
        m=st.sampled_from([2, 6]),
        seed=st.integers(0, 10_000),
    )
    def test_matches_ref(self, n_hidden, m, seed):
        rng = np.random.default_rng(seed)
        h = rng.uniform(0, 1, n_hidden).astype(np.float32)
        y = np.eye(m, dtype=np.float32)[rng.integers(m)]
        # realistic P: SPD-ish diag-dominant
        p = (np.eye(n_hidden) * 5.0 + rand(rng, n_hidden, n_hidden) * 0.05).astype(
            np.float32
        )
        p = ((p + p.T) / 2).astype(np.float32)
        beta = rand(rng, n_hidden, m) * 0.3
        p2, b2 = oselm.oselm_update(h, y, p, beta)
        p2r, b2r = ref.train_step_ref(
            jnp.asarray(h), jnp.asarray(y), jnp.asarray(p), jnp.asarray(beta)
        )
        assert_allclose(np.asarray(p2), np.asarray(p2r), rtol=1e-5, atol=1e-5)
        assert_allclose(np.asarray(b2), np.asarray(b2r), rtol=1e-5, atol=1e-5)

    @given(seed=st.integers(0, 10_000))
    def test_matvec_matches(self, seed):
        rng = np.random.default_rng(seed)
        p = rand(rng, 128, 128)
        h = rand(rng, 128)
        assert_allclose(
            np.asarray(oselm.pl_matvec(p, h)), p @ h, rtol=1e-5, atol=1e-4
        )

    def test_update_shrinks_p(self):
        # P is a covariance-inverse estimate: hᵀP'h < hᵀPh after an update.
        rng = np.random.default_rng(5)
        n_hidden = 32
        h = rng.uniform(0, 1, n_hidden).astype(np.float32)
        p = np.eye(n_hidden, dtype=np.float32) * 10
        beta = np.zeros((n_hidden, 6), dtype=np.float32)
        y = np.eye(6, dtype=np.float32)[0]
        p2, _ = oselm.oselm_update(h, y, p, beta)
        assert h @ np.asarray(p2) @ h < h @ p @ h


# --- predict kernels ---------------------------------------------------------


class TestPredict:
    @given(seed=st.integers(0, 10_000), b=st.sampled_from([1, 8, 64]))
    def test_logits_match(self, seed, b):
        rng = np.random.default_rng(seed)
        h = rng.uniform(0, 1, (b, 128)).astype(np.float32)
        beta = rand(rng, 128, 6) * 0.2
        assert_allclose(
            np.asarray(predict_k.pl_logits(h, beta)), h @ beta, rtol=1e-5, atol=1e-5
        )

    def test_top2(self):
        logits = np.array([[0.1, 0.8, 0.3, -0.2, 0.0, 0.05]], dtype=np.float32)
        cls, p1, p2 = predict_k.top2_stats(logits)
        assert int(cls[0]) == 1
        assert float(p1[0]) == pytest.approx(0.8)
        assert float(p2[0]) == pytest.approx(0.3)

    def test_top2_clamps(self):
        logits = np.array([[1.5, -0.5]], dtype=np.float32)
        _, p1, p2 = predict_k.top2_stats(logits)
        assert float(p1[0]) == 1.0 and float(p2[0]) == 0.0
