"""L2 model graph tests: shapes, semantics, OS-ELM equivalences, DNN step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref


def rand(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


SEED = np.array([11], dtype=np.uint32)


class TestPredictGraphs:
    def test_predict_one_shapes(self):
        rng = np.random.default_rng(0)
        x = rand(rng, 1, model.N_IN)
        beta = rand(rng, 128, model.N_OUT) * 0.1
        logits, h = model.predict_one(x, beta, SEED)
        assert logits.shape == (1, model.N_OUT)
        assert h.shape == (1, 128)

    def test_predict_batch_matches_ref(self):
        rng = np.random.default_rng(1)
        x = rand(rng, 64, model.N_IN)
        beta = rand(rng, 128, model.N_OUT) * 0.1
        got = np.asarray(model.predict_batch(x, beta, SEED))
        want = np.asarray(model.predict_batch_ref(x, beta, SEED))
        assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_predict_one_consistent_with_batch(self):
        rng = np.random.default_rng(2)
        x = rand(rng, 64, model.N_IN)
        beta = rand(rng, 128, model.N_OUT) * 0.1
        batch = np.asarray(model.predict_batch(x, beta, SEED))
        one, _ = model.predict_one(x[:1], beta, SEED)
        assert_allclose(np.asarray(one)[0], batch[0], rtol=1e-5, atol=1e-6)


class TestTrainGraphs:
    def test_train_step_matches_ref(self):
        rng = np.random.default_rng(3)
        x = rand(rng, 1, model.N_IN)
        y = np.eye(model.N_OUT, dtype=np.float32)[4]
        p = np.eye(128, dtype=np.float32) * 3
        beta = rand(rng, 128, model.N_OUT) * 0.1
        p2, b2 = model.train_step(x, y, p, beta, SEED)
        p2r, b2r = model.train_step_ref_graph(x, y, p, beta, SEED)
        assert_allclose(np.asarray(p2), np.asarray(p2r), rtol=1e-5, atol=1e-5)
        assert_allclose(np.asarray(b2), np.asarray(b2r), rtol=1e-5, atol=1e-5)

    def test_sequential_equals_batch_ridge(self):
        """RLS exactness: init on k0 + sequential on rest ≈ batch ridge on all."""
        rng = np.random.default_rng(4)
        n, nh, m, k0, extra = 40, 16, 3, 64, 100
        x_all = rand(rng, k0 + extra, n)
        labels = rng.integers(0, m, k0 + extra)
        y_all = np.eye(m, dtype=np.float32)[labels]

        h_all = np.asarray(ref.hidden_ref(x_all, 5, nh))
        p, beta = ref.init_batch_ref(jnp.asarray(h_all[:k0]), jnp.asarray(y_all[:k0]))
        p, beta = np.asarray(p), np.asarray(beta)
        for i in range(k0, k0 + extra):
            p_j, b_j = ref.train_step_ref(
                jnp.asarray(h_all[i]), jnp.asarray(y_all[i]), jnp.asarray(p), jnp.asarray(beta)
            )
            p, beta = np.asarray(p_j), np.asarray(b_j)

        _, beta_batch = ref.init_batch_ref(jnp.asarray(h_all), jnp.asarray(y_all))
        assert_allclose(beta, np.asarray(beta_batch), atol=5e-3)

    def test_init_batch_newton_schulz_accuracy(self):
        rng = np.random.default_rng(5)
        x0 = rand(rng, 512, model.N_IN)
        y0 = np.eye(model.N_OUT, dtype=np.float32)[rng.integers(0, 6, 512)]
        p0, beta0 = model.init_batch(x0, y0, SEED, n_hidden=128)
        # P0 must invert the Gram matrix
        h0 = np.asarray(ref.hidden_ref(x0, SEED[0], 128))
        gram = h0.T @ h0 + model.LAMBDA * np.eye(128, dtype=np.float32)
        resid = np.abs(gram @ np.asarray(p0) - np.eye(128)).max()
        assert resid < 1e-3, resid
        assert beta0.shape == (128, model.N_OUT)


class TestStoredVariant:
    def test_stored_predict_matches_hash_when_alpha_equal(self):
        rng = np.random.default_rng(6)
        x = rand(rng, 64, model.N_IN)
        beta = rand(rng, 128, model.N_OUT) * 0.1
        scale = np.float32(1.0 / np.sqrt(model.N_IN))
        alpha = ref.counter_alpha_np(int(SEED[0]), model.N_IN, 128, scale)
        got = np.asarray(model.predict_batch_stored(x, alpha, beta))
        want = np.asarray(model.predict_batch(x, beta, SEED))
        assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_stored_train_step(self):
        rng = np.random.default_rng(7)
        x = rand(rng, 1, model.N_IN)
        y = np.eye(model.N_OUT, dtype=np.float32)[0]
        p = np.eye(128, dtype=np.float32) * 2
        beta = rand(rng, 128, model.N_OUT) * 0.1
        alpha = rand(rng, model.N_IN, 128) * 0.04
        p2, b2 = model.train_step_stored(x, y, p, beta, alpha)
        h = np.asarray(ref.hidden_stored_ref(x, alpha))[0]
        p2r, b2r = ref.train_step_ref(
            jnp.asarray(h), jnp.asarray(y), jnp.asarray(p), jnp.asarray(beta)
        )
        assert_allclose(np.asarray(p2), np.asarray(p2r), rtol=1e-5, atol=1e-5)
        assert_allclose(np.asarray(b2), np.asarray(b2r), rtol=1e-5, atol=1e-5)


class TestDnn:
    def test_forward_shapes(self):
        rng = np.random.default_rng(8)
        params = model.dnn_init(jax.random.PRNGKey(0))
        x = rand(rng, 16, model.N_IN)
        logits = model.dnn_forward(x, *params)
        assert logits.shape == (16, model.N_OUT)

    def test_train_step_reduces_loss(self):
        rng = np.random.default_rng(9)
        params = model.dnn_init(jax.random.PRNGKey(1))
        x = rand(rng, 32, model.N_IN)
        y = np.eye(model.N_OUT, dtype=np.float32)[rng.integers(0, 6, 32)]
        lr = np.array([0.1], dtype=np.float32)
        out = model.dnn_train_step(x, y, lr, *params)
        loss0 = float(out[0][0])
        params = out[1:]
        for _ in range(20):
            out = model.dnn_train_step(x, y, lr, *params)
            params = out[1:]
        loss1 = float(out[0][0])
        assert loss1 < loss0 * 0.7, (loss0, loss1)

    def test_newton_schulz_vs_linalg(self):
        rng = np.random.default_rng(10)
        b = rand(rng, 64, 64)
        a = b.T @ b + np.eye(64, dtype=np.float32)
        inv = np.asarray(model.newton_schulz_inverse(jnp.asarray(a)))
        assert_allclose(a @ inv, np.eye(64), atol=1e-3)


class TestTrainStream:
    def test_scan_fused_equals_sequential(self):
        """train_stream (lax.scan) must equal K individual train_steps."""
        rng = np.random.default_rng(11)
        k, nh = 8, 128
        xs = rand(rng, k, model.N_IN)
        labels = rng.integers(0, model.N_OUT, k)
        ys = np.eye(model.N_OUT, dtype=np.float32)[labels]
        p = np.eye(nh, dtype=np.float32) * 4
        beta = rand(rng, nh, model.N_OUT) * 0.1

        p_s, b_s = model.train_stream(xs, ys, p, beta, SEED)

        p_i, b_i = jnp.asarray(p), jnp.asarray(beta)
        for i in range(k):
            p_i, b_i = model.train_step(xs[i : i + 1], ys[i], p_i, b_i, SEED)

        assert_allclose(np.asarray(p_s), np.asarray(p_i), rtol=1e-4, atol=1e-4)
        assert_allclose(np.asarray(b_s), np.asarray(b_i), rtol=1e-4, atol=1e-4)
