"""AOT pipeline tests: manifest round-trip, HLO text sanity, goldens."""

import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestLowering:
    def test_to_hlo_text_roundtrips_simple_fn(self):
        import jax
        import jax.numpy as jnp

        lowered = jax.jit(lambda a, b: (a @ b + 1.0,)).lower(
            aot.spec((2, 2)), aot.spec((2, 2))
        )
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text and "f32[2,2]" in text

    def test_entries_cover_all_variants_and_sizes(self):
        names = [e[0] for e in aot.build_entries()]
        for nh in aot.HIDDEN_SIZES:
            for stem in (
                "predict_one_hash",
                "predict_batch_hash",
                "train_step_hash",
                "init_batch_hash",
                "predict_batch_stored",
                "train_step_stored",
            ):
                assert f"{stem}_n{nh}" in names
        assert "dnn_forward" in names and "dnn_train_step" in names

    def test_lowered_artifacts_have_no_custom_calls(self):
        """CPU-PJRT executability: no LAPACK/Mosaic custom-calls allowed."""
        if not os.path.isdir(ARTIFACT_DIR):
            pytest.skip("artifacts not built")
        for fname in os.listdir(ARTIFACT_DIR):
            if fname.endswith(".hlo.txt"):
                with open(os.path.join(ARTIFACT_DIR, fname)) as f:
                    assert "custom-call" not in f.read(), fname


class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        path = os.path.join(ARTIFACT_DIR, "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            return json.load(f)

    def test_format_and_dims(self, manifest):
        assert manifest["format"] == "hlo-text"
        assert manifest["n_in"] == 561
        assert manifest["n_out"] == 6

    def test_every_artifact_file_exists(self, manifest):
        for name, meta in manifest["artifacts"].items():
            path = os.path.join(ARTIFACT_DIR, meta["path"])
            assert os.path.exists(path), name
            assert os.path.getsize(path) > 500, name

    def test_arg_shapes_recorded(self, manifest):
        m = manifest["artifacts"]["train_step_hash_n128"]
        assert m["arg_shapes"] == [[1, 561], [6], [128, 128], [128, 6], [1]]
        assert m["arg_dtypes"][-1] == "uint32"


class TestGoldens:
    @pytest.fixture(scope="class")
    def goldens(self):
        path = os.path.join(ARTIFACT_DIR, "golden", "numerics.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            return json.load(f)

    def test_stream_matches_ref(self, goldens):
        got = ref.xorshift16_stream(1, 16).tolist()
        assert got == goldens["xorshift16_stream_seed1"]

    def test_alpha_matches_ref(self, goldens):
        got = ref.counter_alpha_np(9, 16, 8, 1.0).reshape(-1)
        np.testing.assert_allclose(got, goldens["counter_alpha_seed9_16x8"], atol=0)

    def test_train_step_golden_selfcheck(self, goldens):
        import jax.numpy as jnp

        g = goldens["train_step"]
        nh = g["n_hidden"]
        h = np.asarray(g["h"], dtype=np.float32)
        p = np.eye(nh, dtype=np.float32) * g["p_diag"]
        beta = np.asarray(g["beta"], dtype=np.float32).reshape(nh, 6)
        y = np.eye(6, dtype=np.float32)[g["y_class"]]
        p2, b2 = ref.train_step_ref(
            jnp.asarray(h), jnp.asarray(y), jnp.asarray(p), jnp.asarray(beta)
        )
        np.testing.assert_allclose(
            np.asarray(p2).reshape(-1), g["p_new"], rtol=1e-6, atol=1e-7
        )
        np.testing.assert_allclose(
            np.asarray(b2).reshape(-1), g["beta_new"], rtol=1e-6, atol=1e-7
        )
