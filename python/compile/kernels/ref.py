"""Pure-jnp/numpy oracle for every Pallas kernel — the correctness reference.

This module is the *normative python half* of the shared numerics spec
(DESIGN.md §6). The rust golden model (`rust/src/odl/xorshift.rs`,
`rust/src/odl/oselm.rs`) implements the same functions; `aot.py` emits
golden vectors from here that the cargo test suite re-checks, so a drift
between the two languages fails tests on both sides.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# --- xorshift16 (paper coefficients 7, 9, 8) -------------------------------

SEED_REMAP = 0x2A6D
ROUNDS = 4
MIX_MUL = 0x9E3779B9
MIX_MUL2 = 0x85EBCA6B


def xs16_round_np(s: np.ndarray) -> np.ndarray:
    """One xorshift(7,9,8) round on uint16 state(s) — numpy version."""
    s = s.astype(np.uint32)  # avoid uint16 overflow warnings; mask manually
    s = s ^ ((s << 7) & 0xFFFF)
    s = s ^ (s >> 9)
    s = s ^ ((s << 8) & 0xFFFF)
    return (s & 0xFFFF).astype(np.uint16)


def xorshift16_stream(seed: int, count: int) -> np.ndarray:
    """The ASIC's *sequential* stream (state after each step), uint16."""
    s = np.uint16(seed if seed != 0 else SEED_REMAP)
    out = np.empty(count, dtype=np.uint16)
    for i in range(count):
        s = xs16_round_np(np.asarray(s))[()]
        out[i] = s
    return out


def counter_alpha_np(seed: int, n: int, cols: int, scale: float) -> np.ndarray:
    """Counter-based α (kernel-identical), numpy. Returns (n, cols) f32."""
    k = np.arange(n * cols, dtype=np.uint64)
    m = (k * MIX_MUL) & 0xFFFFFFFF
    m ^= m >> 15
    m = (m * MIX_MUL2) & 0xFFFFFFFF
    m ^= m >> 13
    s = (np.uint64(seed) ^ (m >> 16) ^ (m & 0xFFFF)) & 0xFFFF
    s = np.where(s == 0, SEED_REMAP, s).astype(np.uint16)
    for _ in range(ROUNDS):
        s = xs16_round_np(s)
    vals = s.view(np.int16).astype(np.float32) / 32768.0
    return (vals * np.float32(scale)).reshape(n, cols)


def counter_alpha(seed, n: int, cols: int, scale: float) -> jnp.ndarray:
    """Counter-based α in jnp (traceable; `seed` may be a traced scalar)."""
    k = jnp.arange(n * cols, dtype=jnp.uint32)
    m = k * jnp.uint32(MIX_MUL)
    m = m ^ (m >> 15)
    m = m * jnp.uint32(MIX_MUL2)
    m = m ^ (m >> 13)
    s = (jnp.asarray(seed, dtype=jnp.uint32) ^ (m >> 16) ^ (m & 0xFFFF)) & 0xFFFF
    s = jnp.where(s == 0, jnp.uint32(SEED_REMAP), s)
    for _ in range(ROUNDS):
        s = s ^ ((s << 7) & 0xFFFF)
        s = s ^ (s >> 9)
        s = s ^ ((s << 8) & 0xFFFF)
        s = s & 0xFFFF
    signed = jnp.where(s >= 32768, s.astype(jnp.int32) - 65536, s.astype(jnp.int32))
    vals = signed.astype(jnp.float32) / 32768.0
    return (vals * jnp.float32(scale)).reshape(n, cols)


# --- OS-ELM reference graph pieces -----------------------------------------


def sigmoid(z):
    return 1.0 / (1.0 + jnp.exp(-z))


def hidden_ref(x, seed, n_hidden: int):
    """H = sigmoid(x · α(seed)) with α counter-generated; x is (B, n)."""
    n = x.shape[-1]
    scale = np.float32(1.0 / np.sqrt(n))
    alpha = counter_alpha(seed, n, n_hidden, scale)
    return sigmoid(x @ alpha)


def hidden_stored_ref(x, alpha):
    """H for the ODLBase (stored-α) variant; alpha is (n, N), pre-scaled."""
    return sigmoid(x @ alpha)


def predict_ref(x, beta, seed):
    """(logits, H) for one batch: logits = H·β (G2 = identity)."""
    h = hidden_ref(x, seed, beta.shape[0])
    return h @ beta, h


def matvec_ref(p, h):
    """Ph = P · h (P is (N,N), h is (N,))."""
    return p @ h


def train_step_ref(h, y, p, beta):
    """One Figure-2(d) sequential update given precomputed H (shape (N,)).

    Returns (P', β'). y is one-hot (m,).
    """
    ph = p @ h
    denom = 1.0 + h @ ph
    p_new = p - jnp.outer(ph, ph) / denom
    err = y - h @ beta
    beta_new = beta + jnp.outer(ph, err) / denom
    return p_new, beta_new


def init_batch_ref(h0, y0, lam: float = 0.01):
    """Batch init: P₀ = (H₀ᵀH₀ + λI)⁻¹, β₀ = P₀·H₀ᵀ·Y₀."""
    n_hidden = h0.shape[1]
    gram = h0.T @ h0 + lam * jnp.eye(n_hidden, dtype=h0.dtype)
    p0 = jnp.linalg.inv(gram)
    beta0 = p0 @ (h0.T @ y0)
    return p0, beta0
