"""L1 Pallas kernel: ODLHash hidden layer.

`H = sigmoid(x · α(seed))` with α **generated inside the kernel** from the
counter-based 16-bit Xorshift — the kernel-level realization of the paper's
ODLHash idea: the α matrix never exists in HBM (on the ASIC: never in SRAM).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the hidden
dimension; each instance holds one `(n, TILE_N)` α block *in registers/VMEM
only*, generated from `broadcasted_iota` + integer xor/shift ops (all VPU-
friendly), then feeds the MXU with an `(B, n) × (n, TILE_N)` matmul.
VMEM per instance @ n=561, TILE_N=128: α block 561·128·4 ≈ 287 kB + x block
≈ B·2.2 kB — comfortably inside a TPU core's ~16 MB VMEM.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact runs
on the rust CPU client (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MIX_MUL, MIX_MUL2, ROUNDS, SEED_REMAP

# Hidden-dimension tile. 128 = MXU lane width; N ∈ {32…512} are multiples
# or fit a single padded tile.
TILE_N = 128


def _alpha_block(seed, n: int, col0, tile_n: int, total_cols: int, scale):
    """Generate the α block for columns [col0, col0+tile_n) — in-kernel.

    Flat weight index k = i·total_cols + (col0 + j) for row i, local col j.
    Mirrors `ref.counter_alpha` / rust `counter_alpha_value` bit-for-bit.
    """
    rows = jax.lax.broadcasted_iota(jnp.uint32, (n, tile_n), 0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (n, tile_n), 1)
    k = rows * jnp.uint32(total_cols) + cols + jnp.uint32(col0)
    m = k * jnp.uint32(MIX_MUL)
    m = m ^ (m >> 15)
    m = m * jnp.uint32(MIX_MUL2)
    m = m ^ (m >> 13)
    s = (jnp.asarray(seed, jnp.uint32) ^ (m >> 16) ^ (m & 0xFFFF)) & 0xFFFF
    s = jnp.where(s == 0, jnp.uint32(SEED_REMAP), s)
    for _ in range(ROUNDS):
        s = s ^ ((s << 7) & 0xFFFF)
        s = s ^ (s >> 9)
        s = s ^ ((s << 8) & 0xFFFF)
        s = s & 0xFFFF
    signed = jnp.where(s >= 32768, s.astype(jnp.int32) - 65536, s.astype(jnp.int32))
    return signed.astype(jnp.float32) / 32768.0 * scale


def _hash_hidden_kernel(seed_ref, x_ref, h_ref, *, n: int, n_hidden: int, scale: float):
    """One grid instance: H tile = sigmoid(x · α_tile(seed))."""
    j = pl.program_id(0)
    tile = h_ref.shape[-1]
    col0 = j * tile
    alpha = _alpha_block(seed_ref[0], n, col0, tile, n_hidden, jnp.float32(scale))
    z = x_ref[...] @ alpha  # (B, n) x (n, tile) -> MXU
    h_ref[...] = 1.0 / (1.0 + jnp.exp(-z))


@functools.partial(jax.jit, static_argnames=("n_hidden",))
def hash_hidden(x, seed, n_hidden: int):
    """H = sigmoid(x · α(seed)) for x of shape (B, n). seed: scalar int32/uint32.

    Pads the hidden dim up to a TILE_N multiple and slices the result back.
    """
    b, n = x.shape
    scale = float(1.0 / (n ** 0.5))
    tile = min(TILE_N, n_hidden)
    padded = ((n_hidden + tile - 1) // tile) * tile
    grid = padded // tile
    seed_arr = jnp.asarray(seed, dtype=jnp.uint32).reshape((1,))
    h = pl.pallas_call(
        functools.partial(
            _hash_hidden_kernel, n=n, n_hidden=n_hidden, scale=scale
        ),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda j: (0,)),  # seed: broadcast to every tile
            pl.BlockSpec((b, n), lambda j: (0, 0)),  # x: whole batch per tile
        ],
        out_specs=pl.BlockSpec((b, tile), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, padded), jnp.float32),
        interpret=True,
    )(seed_arr, x)
    return h[:, :n_hidden]


def _stored_hidden_kernel(x_ref, alpha_ref, h_ref):
    z = x_ref[...] @ alpha_ref[...]
    h_ref[...] = 1.0 / (1.0 + jnp.exp(-z))


@jax.jit
def stored_hidden(x, alpha):
    """ODLBase variant: H = sigmoid(x · α) with stored (pre-scaled) α."""
    b, n = x.shape
    n_hidden = alpha.shape[1]
    tile = min(TILE_N, n_hidden)
    padded = ((n_hidden + tile - 1) // tile) * tile
    grid = padded // tile
    if padded != n_hidden:
        alpha = jnp.pad(alpha, ((0, 0), (0, padded - n_hidden)))
    h = pl.pallas_call(
        _stored_hidden_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((b, n), lambda j: (0, 0)),
            pl.BlockSpec((n, tile), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((b, tile), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, padded), jnp.float32),
        interpret=True,
    )(x, alpha)
    return h[:, :n_hidden]
