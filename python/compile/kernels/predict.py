"""L1 Pallas kernel: output layer + top-2 statistics.

`O = H·β` (G2 = identity — see the Prediction docs in
rust/src/odl/activation.rs), plus the per-sample (argmax, p1, p2) triple
that feeds the P1P2 pruning gate. m = 6 is tiny, so one instance handles
the whole output; the batch dimension is the grid.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_B = 64


def _logits_kernel(h_ref, beta_ref, o_ref):
    o_ref[...] = h_ref[...] @ beta_ref[...]


@jax.jit
def pl_logits(h, beta):
    """O = H·β, H: (B, N), β: (N, m) → (B, m)."""
    b, n = h.shape
    m = beta.shape[1]
    tile_b = min(TILE_B, b)
    assert b % tile_b == 0, "batch must be a multiple of the tile"
    grid = b // tile_b
    return pl.pallas_call(
        _logits_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile_b, n), lambda i: (i, 0)),
            pl.BlockSpec((n, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=True,
    )(h, beta)


@jax.jit
def top2_stats(logits):
    """Per-row (class, p1, p2): top-2 of the raw outputs, clamped to [0,1].

    Mirrors rust `Prediction::from_logits`.
    """
    top, idx = jax.lax.top_k(logits, 2)
    p1 = jnp.clip(top[..., 0], 0.0, 1.0)
    p2 = jnp.clip(top[..., 1], 0.0, 1.0)
    return idx[..., 0].astype(jnp.int32), p1, p2
