"""L1 Pallas kernels: the OS-ELM sequential update (Figure 2(d)).

Split into the two phases the ASIC's state machine also uses:

1. `pl_matvec`  — `Ph = P·h`, tiled over P's rows (each instance reads a
   row block of P plus the whole h vector: one VMEM-resident streaming
   pass over P, the large operand).
2. `pl_rank1_update` — given Ph and the scalar 1/denom, update both P
   (`P ← P − Ph·Phᵀ·inv_denom`) and β (`β ← β + Ph·errᵀ·inv_denom`) in one
   tiled sweep. The scalar division happens ONCE outside the sweep
   (multiply-by-reciprocal inside), exactly like the hardware divider
   schedule — and unlike a naive per-element division, which would be
   ~40× more divider cycles (see rust/src/hw/cycles.rs).

All `interpret=True` (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 128


def _matvec_kernel(p_ref, h_ref, ph_ref):
    # (tile, N) x (N,) -> (tile,)
    ph_ref[...] = p_ref[...] @ h_ref[...]


@jax.jit
def pl_matvec(p, h):
    """Ph = P·h, P: (N, N), h: (N,) → (N,)."""
    n = p.shape[0]
    tile = min(TILE_ROWS, n)
    assert n % tile == 0, "N must be a multiple of the row tile"
    grid = n // tile
    return pl.pallas_call(
        _matvec_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(p, h)


def _rank1_kernel(ph_ref, err_ref, inv_denom_ref, p_ref, beta_ref, p_out_ref, b_out_ref):
    i = pl.program_id(0)
    tile = p_out_ref.shape[0]
    row0 = i * tile
    inv = inv_denom_ref[0]
    ph_all = ph_ref[...]  # (N,)
    ph_rows = jax.lax.dynamic_slice(ph_all, (row0,), (tile,))  # this tile's Ph rows
    scale = ph_rows * inv  # (tile,)
    # P rows: P[i,:] -= scale_i * Ph
    p_out_ref[...] = p_ref[...] - scale[:, None] * ph_all[None, :]
    # β rows: β[i,:] += scale_i * err
    b_out_ref[...] = beta_ref[...] + scale[:, None] * err_ref[...][None, :]


@jax.jit
def pl_rank1_update(p, beta, ph, err, inv_denom):
    """(P', β') = (P − Ph·Phᵀ·inv, β + Ph·errᵀ·inv), tiled over rows."""
    n = p.shape[0]
    m = beta.shape[1]
    tile = min(TILE_ROWS, n)
    assert n % tile == 0
    grid = n // tile
    inv_arr = jnp.asarray(inv_denom, jnp.float32).reshape((1,))
    return pl.pallas_call(
        _rank1_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),      # ph (whole vector)
            pl.BlockSpec((m,), lambda i: (0,)),      # err
            pl.BlockSpec((1,), lambda i: (0,)),      # inv_denom scalar
            pl.BlockSpec((tile, n), lambda i: (i, 0)),  # P row tile
            pl.BlockSpec((tile, m), lambda i: (i, 0)),  # beta row tile
        ],
        out_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((tile, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, n), jnp.float32),
            jax.ShapeDtypeStruct((n, m), jnp.float32),
        ],
        interpret=True,
    )(ph, err, inv_arr, p, beta)


def oselm_update(h, y, p, beta):
    """Full sequential update from hidden activations h (N,) and one-hot y.

    Composes the two kernels + the single scalar division.
    """
    ph = pl_matvec(p, h)
    denom = 1.0 + jnp.dot(h, ph)
    inv_denom = 1.0 / denom
    err = y - h @ beta
    return pl_rank1_update(p, beta, ph, err, inv_denom)
