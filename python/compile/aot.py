"""AOT lowering: every L2 graph → HLO *text* + a manifest the rust runtime
reads, plus golden vectors for the cross-language numerics tests.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids that the
xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

N_IN = model.N_IN
N_OUT = model.N_OUT
# Hidden sizes the experiments use (Table 3 / Figure 3 focus on 128/256).
HIDDEN_SIZES = (128, 256)
# Batched-eval batch (rust pads the tail batch).
EVAL_BATCH = 256
# Batch-init sample count (≥ max N; protocol uses 2N capped by this).
INIT_K0 = 512
# Scan-fused streaming-train chunk (one XLA launch per K samples).
STREAM_K = 32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_entry(fn, args):
    return to_hlo_text(jax.jit(fn).lower(*args))


def build_entries():
    """(name, fn, example-arg specs, metadata) for every artifact."""
    entries = []
    u32v = functools.partial(spec, dtype=jnp.uint32)

    for n_hidden in HIDDEN_SIZES:
        nh = n_hidden

        entries.append(
            (
                f"predict_one_hash_n{nh}",
                model.predict_one,
                [spec((1, N_IN)), spec((nh, N_OUT)), u32v((1,))],
                {
                    "variant": "hash",
                    "n_hidden": nh,
                    "inputs": ["x[1,n]", "beta[N,m]", "seed[1]u32"],
                    "outputs": ["logits[1,m]", "h[1,N]"],
                },
            )
        )
        entries.append(
            (
                f"predict_batch_hash_n{nh}",
                model.predict_batch,
                [spec((EVAL_BATCH, N_IN)), spec((nh, N_OUT)), u32v((1,))],
                {
                    "variant": "hash",
                    "n_hidden": nh,
                    "batch": EVAL_BATCH,
                    "inputs": ["x[B,n]", "beta[N,m]", "seed[1]u32"],
                    "outputs": ["logits[B,m]"],
                },
            )
        )
        entries.append(
            (
                f"train_step_hash_n{nh}",
                model.train_step,
                [
                    spec((1, N_IN)),
                    spec((N_OUT,)),
                    spec((nh, nh)),
                    spec((nh, N_OUT)),
                    u32v((1,)),
                ],
                {
                    "variant": "hash",
                    "n_hidden": nh,
                    "inputs": ["x[1,n]", "y[m]", "P[N,N]", "beta[N,m]", "seed[1]u32"],
                    "outputs": ["P'[N,N]", "beta'[N,m]"],
                },
            )
        )
        entries.append(
            (
                f"train_stream_hash_n{nh}",
                model.train_stream,
                [
                    spec((STREAM_K, N_IN)),
                    spec((STREAM_K, N_OUT)),
                    spec((nh, nh)),
                    spec((nh, N_OUT)),
                    u32v((1,)),
                ],
                {
                    "variant": "hash",
                    "n_hidden": nh,
                    "k": STREAM_K,
                    "inputs": ["xs[K,n]", "ys[K,m]", "P[N,N]", "beta[N,m]", "seed[1]u32"],
                    "outputs": ["P'[N,N]", "beta'[N,m]"],
                },
            )
        )
        entries.append(
            (
                f"init_batch_hash_n{nh}",
                functools.partial(model.init_batch, n_hidden=nh),
                [spec((INIT_K0, N_IN)), spec((INIT_K0, N_OUT)), u32v((1,))],
                {
                    "variant": "hash",
                    "n_hidden": nh,
                    "k0": INIT_K0,
                    "inputs": ["x0[k0,n]", "y0[k0,m]", "seed[1]u32"],
                    "outputs": ["P0[N,N]", "beta0[N,m]"],
                },
            )
        )
        entries.append(
            (
                f"predict_batch_stored_n{nh}",
                model.predict_batch_stored,
                [spec((EVAL_BATCH, N_IN)), spec((N_IN, nh)), spec((nh, N_OUT))],
                {
                    "variant": "stored",
                    "n_hidden": nh,
                    "batch": EVAL_BATCH,
                    "inputs": ["x[B,n]", "alpha[n,N]", "beta[N,m]"],
                    "outputs": ["logits[B,m]"],
                },
            )
        )
        entries.append(
            (
                f"train_step_stored_n{nh}",
                model.train_step_stored,
                [
                    spec((1, N_IN)),
                    spec((N_OUT,)),
                    spec((nh, nh)),
                    spec((nh, N_OUT)),
                    spec((N_IN, nh)),
                ],
                {
                    "variant": "stored",
                    "n_hidden": nh,
                    "inputs": ["x[1,n]", "y[m]", "P[N,N]", "beta[N,m]", "alpha[n,N]"],
                    "outputs": ["P'[N,N]", "beta'[N,m]"],
                },
            )
        )

    # DNN baseline: forward + one SGD step.
    l1, l2, l3, l4 = model.DNN_LAYERS
    dnn_params = [
        spec((l1, l2)),
        spec((l2,)),
        spec((l2, l3)),
        spec((l3,)),
        spec((l3, l4)),
        spec((l4,)),
    ]
    entries.append(
        (
            "dnn_forward",
            model.dnn_forward,
            [spec((EVAL_BATCH, N_IN))] + dnn_params,
            {
                "variant": "dnn",
                "batch": EVAL_BATCH,
                "layers": list(model.DNN_LAYERS),
                "inputs": ["x[B,n]", "w1", "b1", "w2", "b2", "w3", "b3"],
                "outputs": ["logits[B,m]"],
            },
        )
    )
    entries.append(
        (
            "dnn_train_step",
            model.dnn_train_step,
            [spec((32, N_IN)), spec((32, N_OUT)), spec((1,))] + dnn_params,
            {
                "variant": "dnn",
                "batch": 32,
                "layers": list(model.DNN_LAYERS),
                "inputs": ["x[B,n]", "y[B,m]", "lr[1]", "w1", "b1", "w2", "b2", "w3", "b3"],
                "outputs": ["loss[1]", "w1'", "b1'", "w2'", "b2'", "w3'", "b3'"],
            },
        )
    )
    return entries


def emit_goldens(out_dir: str) -> None:
    """Golden vectors for the rust ↔ python numerics cross-checks."""
    golden_dir = os.path.join(out_dir, "golden")
    os.makedirs(golden_dir, exist_ok=True)

    # 1. Sequential xorshift16 stream.
    stream = ref.xorshift16_stream(1, 16).tolist()
    # 2. Counter-based α block (seed 9, 16×8 — mirrors the rust unit test).
    alpha = ref.counter_alpha_np(9, 16, 8, 1.0).reshape(-1).tolist()
    # 3. Hidden layer on a deterministic input (561 → 128, seed 7).
    x = (np.arange(N_IN, dtype=np.float32) % 17 - 8.0) / 8.0
    h = np.asarray(ref.hidden_ref(x[None, :], 7, 128))[0]
    # 4. One train step from a deterministic state.
    nh = 8
    hsmall = np.asarray(ref.hidden_ref(x[None, :nh * 4], 3, nh))[0]
    p = np.eye(nh, dtype=np.float32) * 5.0
    beta = np.linspace(-0.5, 0.5, nh * N_OUT, dtype=np.float32).reshape(nh, N_OUT)
    y = np.eye(N_OUT, dtype=np.float32)[1]
    p2, b2 = ref.train_step_ref(
        jnp.asarray(hsmall), jnp.asarray(y), jnp.asarray(p), jnp.asarray(beta)
    )

    goldens = {
        "xorshift16_stream_seed1": stream,
        "counter_alpha_seed9_16x8": alpha,
        "hidden_n561_N128_seed7": h.tolist(),
        "train_step": {
            "n_hidden": nh,
            "h": hsmall.tolist(),
            "p_diag": 5.0,
            "beta": beta.reshape(-1).tolist(),
            "y_class": 1,
            "p_new": np.asarray(p2).reshape(-1).tolist(),
            "beta_new": np.asarray(b2).reshape(-1).tolist(),
        },
    }
    with open(os.path.join(golden_dir, "numerics.json"), "w") as f:
        json.dump(goldens, f)
    print(f"wrote golden vectors to {golden_dir}/numerics.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", help="lower only artifacts whose name contains this")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": "hlo-text", "n_in": N_IN, "n_out": N_OUT, "artifacts": {}}
    for name, fn, arg_specs, meta in build_entries():
        if args.only and args.only not in name:
            continue
        text = lower_entry(fn, arg_specs)
        path = f"{name}.hlo.txt"
        with open(os.path.join(args.out, path), "w") as f:
            f.write(text)
        meta = dict(meta)
        meta["path"] = path
        meta["arg_shapes"] = [list(s.shape) for s in arg_specs]
        meta["arg_dtypes"] = [str(s.dtype) for s in arg_specs]
        manifest["artifacts"][name] = meta
        print(f"lowered {name}: {len(text)} chars")

    emit_goldens(args.out)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
