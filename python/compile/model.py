"""L2: the OS-ELM compute graphs (and the DNN baseline), composed from the
L1 Pallas kernels, exactly as AOT-lowered into `artifacts/*.hlo.txt`.

Every public function here is a *jit-able graph* whose HLO the rust runtime
executes via PJRT. Python never runs at request time: `aot.py` lowers each
graph once per (variant, N) and the rust side binds inputs by position
(see `artifacts/manifest.json` for names/shapes).

Seeds are uint32 scalars passed as shape-(1,) arrays (scalar-literal
plumbing through PJRT is dialect-dependent; a 1-element vector is not).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import hash_elm, oselm, predict as predict_k
from .kernels.ref import counter_alpha  # noqa: F401  (re-exported for tests)

# Paper prototype dimensions.
N_IN = 561
N_OUT = 6
LAMBDA = 0.01


# --- ODLHash graphs ---------------------------------------------------------


def predict_one(x, beta, seed):
    """x: (1, n), β: (N, m), seed: (1,) u32 → (logits (1, m), H (1, N))."""
    h = hash_elm.hash_hidden(x, seed[0], beta.shape[0])
    logits = predict_k.pl_logits(h, beta)
    return logits, h


def predict_batch(x, beta, seed):
    """Batched evaluation: x (B, n) → logits (B, m). B must be tile-aligned."""
    h = hash_elm.hash_hidden(x, seed[0], beta.shape[0])
    return predict_k.pl_logits(h, beta)


def train_step(x, y, p, beta, seed):
    """One sequential update: x (1, n), y one-hot (m,) → (P', β')."""
    h = hash_elm.hash_hidden(x, seed[0], beta.shape[0])[0]
    return oselm.oselm_update(h, y, p, beta)


def train_stream(xs, ys, p, beta, seed):
    """K sequential updates fused into one executable via `lax.scan` —
    the L2 throughput optimization for streaming training: one XLA launch
    (and one P/β host round-trip) amortizes over K samples instead of 1.

    xs: (K, n), ys: (K, m) one-hot → (P', β').
    The hidden activations for all K samples are computed in one batched
    Pallas call (MXU-shaped); the inherently sequential rank-1 updates run
    inside the scan with plain jnp ops (same math as the oselm kernel —
    equivalence is pytest-checked).
    """
    h_all = hash_elm.hash_hidden(xs, seed[0], beta.shape[0])  # (K, N)

    def step(carry, inputs):
        p, beta = carry
        h, y = inputs
        ph = p @ h
        denom = 1.0 + h @ ph
        inv = 1.0 / denom
        p = p - jnp.outer(ph, ph) * inv
        beta = beta + jnp.outer(ph, y - h @ beta) * inv
        return (p, beta), ()

    (p, beta), _ = jax.lax.scan(step, (p, beta), (h_all, ys))
    return p, beta


def newton_schulz_inverse(a, iters: int = 40):
    """SPD matrix inverse by Newton–Schulz iteration — pure matmuls.

    Why not `jnp.linalg.inv`: on CPU it lowers to LAPACK *FFI* custom-calls
    (`lapack_sgetrf_ffi`) that the pinned xla_extension 0.5.1 runtime does
    not register, so the artifact would not execute from rust. On the MXU
    an iterative inverse is the natural choice anyway (no LAPACK on TPUs
    either — same hardware-adaptation as the kernels).

    X₀ = I/‖A‖_F guarantees eig(I − X₀A) ⊂ [0, 1) for SPD A, so
    X_{k+1} = X_k(2I − A·X_k) converges monotonically; `iters` = 40 covers
    condition numbers up to ~10⁶ in f32.
    """
    n = a.shape[0]
    eye2 = 2.0 * jnp.eye(n, dtype=a.dtype)
    x = jnp.eye(n, dtype=a.dtype) / jnp.linalg.norm(a)

    def body(_, x):
        return x @ (eye2 - a @ x)

    return jax.lax.fori_loop(0, iters, body, x)


def init_batch(x0, y0, seed, n_hidden: int):
    """Batch init on k₀ samples: → (P₀, β₀)."""
    h0 = hash_elm.hash_hidden(x0, seed[0], n_hidden)
    gram = h0.T @ h0 + LAMBDA * jnp.eye(n_hidden, dtype=jnp.float32)
    p0 = newton_schulz_inverse(gram)
    beta0 = p0 @ (h0.T @ y0)
    return p0, beta0


# --- ODLBase (stored-α) graphs ----------------------------------------------


def predict_batch_stored(x, alpha, beta):
    h = hash_elm.stored_hidden(x, alpha)
    return predict_k.pl_logits(h, beta)


def train_step_stored(x, y, p, beta, alpha):
    h = hash_elm.stored_hidden(x, alpha)[0]
    return oselm.oselm_update(h, y, p, beta)


# --- DNN baseline (561, 512, 256, 6) ----------------------------------------
#
# Params travel as a flat tuple (w1, b1, w2, b2, w3, b3) so the PJRT call
# signature stays positional.

DNN_LAYERS = (561, 512, 256, 6)


def dnn_init(key):
    """He-init parameters for the (561,512,256,6) MLP."""
    params = []
    keys = jax.random.split(key, len(DNN_LAYERS) - 1)
    for k, (fan_in, fan_out) in zip(keys, zip(DNN_LAYERS[:-1], DNN_LAYERS[1:])):
        w = jax.random.normal(k, (fan_in, fan_out), jnp.float32) * np.sqrt(
            2.0 / fan_in
        ).astype(np.float32)
        params += [w, jnp.zeros((fan_out,), jnp.float32)]
    return tuple(params)


def dnn_forward(x, w1, b1, w2, b2, w3, b3):
    """Logits for x (B, 561)."""
    a1 = jnp.maximum(x @ w1 + b1, 0.0)
    a2 = jnp.maximum(a1 @ w2 + b2, 0.0)
    return a2 @ w3 + b3


def _dnn_loss(params, x, y):
    logits = dnn_forward(x, *params)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(y * logp, axis=-1))


def dnn_train_step(x, y, lr, w1, b1, w2, b2, w3, b3):
    """One SGD step on a minibatch; returns (loss, new params...)."""
    params = (w1, b1, w2, b2, w3, b3)
    loss, grads = jax.value_and_grad(_dnn_loss)(params, x, y)
    new = tuple(p - lr[0] * g for p, g in zip(params, grads))
    return (loss.reshape((1,)),) + new


# --- reference twins (pure jnp, no pallas) — used by pytest ------------------


def predict_batch_ref(x, beta, seed):
    from .kernels import ref

    logits, _ = ref.predict_ref(x, beta, seed[0])
    return logits


def train_step_ref_graph(x, y, p, beta, seed):
    from .kernels import ref

    h = ref.hidden_ref(x, seed[0], beta.shape[0])[0]
    return ref.train_step_ref(h, y, p, beta)
